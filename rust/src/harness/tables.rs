//! Table harnesses: Tables 2, 4, 6, 7 and the §5.4 V100 validation.

use super::{run_method, run_methods, HarnessOpts, Method};
use crate::baselines::mist;
use crate::graph::models;
use crate::graph::subgraph::SgConfig;
use crate::hw::GIB;
use crate::memory::{MemSpec, ZeroStage};
use crate::network::Cluster;
use crate::sim::{simulate, Schedule};
use crate::solver::exact::{solve_exact, ExactOpts};
use crate::solver::{solve as nest_solve, SolverOpts};
use crate::util::csv::Csv;
use crate::util::table::Table;

/// Table 2: distributed strategies found per method at 512 devices
/// (fat-tree TPUv4), formatted `{p, d, t, s, (e, c)}`.
pub fn table2(opts: &HarnessOpts) {
    println!("== Table 2: strategies at 512 devices (fat-tree TPUv4) ==");
    let cluster = Cluster::fat_tree_tpuv4(512);
    let methods = [
        Method::Manual,
        Method::Mcmc,
        Method::AlpaE,
        Method::Phaze,
        Method::Nest,
    ];
    let mut header = vec!["model"];
    header.extend(methods.iter().map(|m| m.name()));
    header.push("recompute");
    let mut tbl = Table::new(&header);
    let mut csv = Csv::new(&["model", "method", "strategy", "recompute"]);
    for model in [
        "llama2-7b",
        "llama3-70b",
        "bertlarge",
        "gpt3-175b",
        "mixtral-8x7b",
    ] {
        let graph = models::by_name(model, 1).unwrap();
        let results = run_methods(&graph, &cluster, &methods, opts);
        let mut row = vec![model.to_string()];
        let mut nest_rc = String::new();
        for r in &results {
            row.push(r.strategy());
            if r.method == Method::Nest {
                nest_rc = r
                    .plan
                    .as_ref()
                    .map(|p| {
                        if p.stages.iter().any(|s| s.mem.recompute) {
                            "Recomputation".to_string()
                        } else {
                            "Stashing".to_string()
                        }
                    })
                    .unwrap_or_default();
            }
            csv.row(vec![
                model.into(),
                r.method.name().into(),
                r.strategy(),
                String::new(),
            ]);
        }
        row.push(nest_rc);
        tbl.row(row);
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/table2.csv", opts.results_dir));
}

/// Table 4: solver runtime, NEST vs Mist (spine-leaf H100). The paper
/// reports wall-clock minutes on their testbed; shapes — who is faster,
/// by roughly how much — are the reproduction target. NEST runs twice:
/// serial and with the multi-threaded outer enumeration
/// (`opts.solver.threads`, 0 = all cores), whose plans are identical by
/// construction — the "threads" column is pure wall-clock reduction.
pub fn table4(opts: &HarnessOpts, n_devices: usize) {
    println!("== Table 4: solver runtime comparison (spine-leaf {n_devices}×H100) ==");
    let cluster = Cluster::spine_leaf_h100(n_devices, 2.0);
    let mut tbl = Table::new(&[
        "model",
        "mist",
        "nest (1 thread)",
        "nest (parallel)",
        "vs mist",
        "thread speedup",
    ]);
    let mut csv = Csv::new(&[
        "model",
        "mist_s",
        "nest_1t_s",
        "nest_mt_s",
        "reduction_pct",
        "thread_speedup",
    ]);
    let serial_opts = SolverOpts {
        threads: 1,
        ..opts.solver.clone()
    };
    for model in ["gpt3-35b", "llama3-70b", "llama2-7b", "bertlarge"] {
        let graph = models::by_name(model, 1).unwrap();
        let t0 = std::time::Instant::now();
        let mist_ok = mist::solve(&graph, &cluster).is_some();
        let mist_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let nest_1t = nest_solve(&graph, &cluster, &serial_opts);
        let nest_1t_s = t0.elapsed().as_secs_f64();
        let (nest_mt, nest_mt_s) = if opts.solver.threads == 1 {
            // Parallel run would be an identical serial duplicate.
            (nest_1t.clone(), nest_1t_s)
        } else {
            let t0 = std::time::Instant::now();
            let sol = nest_solve(&graph, &cluster, &opts.solver);
            (sol, t0.elapsed().as_secs_f64())
        };
        debug_assert_eq!(
            nest_1t.as_ref().map(|s| &s.plan),
            nest_mt.as_ref().map(|s| &s.plan),
            "{model}: thread count changed the plan"
        );
        let reduction = if mist_ok && mist_s > 0.0 {
            (1.0 - nest_mt_s / mist_s) * 100.0
        } else {
            f64::NAN
        };
        let speedup = nest_1t_s / nest_mt_s.max(1e-12);
        let fmt_or_x = |ok: bool, s: f64| {
            if ok {
                crate::util::table::fmt_time(s)
            } else {
                "✗".into()
            }
        };
        tbl.row(vec![
            model.into(),
            fmt_or_x(mist_ok, mist_s),
            fmt_or_x(nest_1t.is_some(), nest_1t_s),
            fmt_or_x(nest_mt.is_some(), nest_mt_s),
            format!("{reduction:.1}%"),
            format!("{speedup:.2}x"),
        ]);
        csv.row(vec![
            model.into(),
            mist_s.to_string(),
            nest_1t_s.to_string(),
            nest_mt_s.to_string(),
            reduction.to_string(),
            speedup.to_string(),
        ]);
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/table4.csv", opts.results_dir));
}

/// Table 6: per-layer memory estimates. Two validations:
/// 1. NEST's analytical per-block estimate vs the paper's published
///    Alpa-compiled-executable measurements (the ≤7% claim).
/// 2. Exact cross-check against the L2 JAX model: the manifest's true
///    parameter count vs the Rust graph formula for the same config.
pub fn table6(opts: &HarnessOpts) {
    println!("== Table 6: per-layer memory estimation ==");
    // (model, tp used in the paper's Alpa executables, published GB).
    let rows = [
        ("gpt3-175b", 8usize, 10.1),
        ("llama3-70b", 1, 24.8),
        ("llama2-7b", 1, 9.8),
        ("bertlarge", 1, 0.21),
    ];
    let mut tbl = Table::new(&["model", "Alpa executables (GB)", "NEST estimate (GB)", "deviation"]);
    let mut csv = Csv::new(&["model", "published_gb", "estimate_gb", "deviation_pct"]);
    let mut devs = Vec::new();
    for (model, tp, published) in rows {
        let graph = models::by_name(model, 1).unwrap();
        let block = &graph.layers[1];
        let sg = SgConfig {
            tp,
            sp: tp > 1,
            ep: 1,
            cp: 1,
        };
        let spec = MemSpec::plain();
        let bytes = crate::memory::stage_peak_bytes(
            std::slice::from_ref(block),
            graph.tokens,
            &sg,
            &spec,
            0,
        );
        let gb = bytes / 1e9;
        let dev = (gb - published).abs() / published * 100.0;
        devs.push(dev);
        tbl.row(vec![
            model.into(),
            format!("{published}"),
            format!("{gb:.2}"),
            format!("{dev:.1}%"),
        ]);
        csv.row(vec![
            model.into(),
            published.to_string(),
            gb.to_string(),
            dev.to_string(),
        ]);
    }
    println!("{}", tbl.render());
    println!(
        "mean deviation vs published Alpa executables: {:.1}% (paper: ~7%)",
        crate::util::stats::mean(&devs)
    );

    // Exact parameter-count validation against the real L2 model.
    if let Some(dir) = crate::runtime::artifacts_dir() {
        if let Ok(man) = crate::runtime::manifest::Manifest::load(dir.join("manifest.json")) {
            let c = &man.config;
            let g = models::tiny_transformer(c.n_layers, c.hidden, c.seq, c.mbs);
            // Rebuild with matching vocab/intermediate for the check.
            let analytic: f64 = g
                .layers
                .iter()
                .map(|l| match l.kind {
                    crate::graph::LayerKind::Embedding | crate::graph::LayerKind::Head => {
                        (c.vocab * c.hidden) as f64
                    }
                    _ => {
                        let h = c.hidden as f64;
                        4.0 * h * h + 2.0 * h * c.intermediate as f64
                    }
                })
                .sum();
            let actual = c.param_count as f64;
            let err = (analytic - actual).abs() / actual * 100.0;
            println!(
                "L2 ground truth: manifest params {} vs analytical {:.0} ({err:.2}% — bias/LN terms excluded)",
                actual, analytic
            );
        }
    }
    let _ = csv.write(format!("{}/table6.csv", opts.results_dir));
}

/// Table 7: ZeRO ablation under memory-constrained accelerators
/// (Llama3-70B at 24 GB, BertLarge at 120 MB). Shows the strategy chosen,
/// the per-stage ZeRO configuration, and that plain placement (ZeRO
/// disabled) is infeasible.
pub fn table7(opts: &HarnessOpts) {
    println!("== Table 7: ZeRO ablation on resource-constrained accelerators ==");
    let mut tbl = Table::new(&["model", "HBM", "devices", "strategy", "ZeRO usage", "without ZeRO"]);
    let mut csv = Csv::new(&["model", "hbm", "strategy", "zero", "feasible_without"]);
    for (model, cap_bytes, cap_name, devices) in [
        ("llama3-70b", 24.0 * GIB, "24GB", 1024usize),
        ("bertlarge", 120e6, "120MB", 1024),
    ] {
        let graph = models::by_name(model, 1).unwrap();
        let mut cluster = Cluster::fat_tree_tpuv4(devices);
        cluster.shrink_capacity(cap_bytes);

        let sol = nest_solve(&graph, &cluster, &opts.solver);
        let no_zero = nest_solve(
            &graph,
            &cluster,
            &SolverOpts {
                zero_max_degree: 1,
                try_recompute: opts.solver.try_recompute,
                ..opts.solver.clone()
            },
        );
        let (strategy, zero_desc) = match &sol {
            Some(s) => {
                let mut zeros: Vec<String> = Vec::new();
                let mut last: Option<(ZeroStage, usize, usize)> = None;
                for (k, st) in s.plan.stages.iter().enumerate() {
                    match &mut last {
                        Some((z, _, hi)) if *z == st.mem.zero => *hi = k,
                        _ => {
                            if let Some((z, lo, hi)) = last.take() {
                                zeros.push(format!("stages {lo}-{hi}: {}", z.describe()));
                            }
                            last = Some((st.mem.zero, k, k));
                        }
                    }
                }
                if let Some((z, lo, hi)) = last {
                    zeros.push(format!("stages {lo}-{hi}: {}", z.describe()));
                }
                (s.plan.strategy_string(), zeros.join("; "))
            }
            None => ("✗".into(), "-".into()),
        };
        let without = match &no_zero {
            Some(s) if s.plan.stages.iter().all(|st| st.mem.zero == ZeroStage::None) => {
                format!("feasible ({})", s.plan.strategy_string())
            }
            Some(s) => format!("needs ZeRO ({})", s.plan.strategy_string()),
            None => "infeasible".into(),
        };
        tbl.row(vec![
            model.into(),
            cap_name.into(),
            sol.as_ref()
                .map(|s| s.plan.used_devices().to_string())
                .unwrap_or_default(),
            strategy.clone(),
            zero_desc.clone(),
            without.clone(),
        ]);
        csv.row(vec![
            model.into(),
            cap_name.into(),
            strategy,
            zero_desc,
            without,
        ]);
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/table7.csv", opts.results_dir));
}

/// §5.4: V100 validation clusters (8 and 16 devices, 2×V100 per node).
/// Compares the exact NEST solver against Alpa(-O analog) on the scaled
/// Mixtral, reporting throughput ratio and optimization time (paper:
/// within 7% at 8 GPUs, 1.8× at 16, 5 min vs 1 h search).
pub fn v100_validation(opts: &HarnessOpts) {
    println!("== §5.4: V100 spine-leaf validation (scaled Mixtral-790M) ==");
    let mut tbl = Table::new(&[
        "cluster", "method", "strategy", "throughput (samples/s)", "vs alpa", "solve time",
    ]);
    let mut csv = Csv::new(&["devices", "method", "strategy", "throughput", "solve_s"]);
    for n in [8usize, 16] {
        let graph = models::mixtral_scaled(1);
        let cluster = Cluster::v100_cluster(n);
        let alpa = run_method(&graph, &cluster, Method::AlpaE, opts);

        // NEST's exact small-cluster solver (the full Algorithm 1 state
        // space), replicating pipelines when beneficial.
        let t0 = std::time::Instant::now();
        let mut best: Option<crate::solver::Solution> = None;
        for d in [1usize, 2, 4] {
            if n % d != 0 {
                continue;
            }
            for rc in [false, true] {
                let sol = solve_exact(
                    &graph,
                    &cluster,
                    &ExactOpts {
                        max_stages: 8,
                        dp_width: d,
                        recompute: rc,
                        threads: opts.solver.threads,
                        ..Default::default()
                    },
                );
                if let Some(s) = sol {
                    if best
                        .as_ref()
                        .map(|b| s.plan.batch_time < b.plan.batch_time)
                        .unwrap_or(true)
                    {
                        best = Some(s);
                    }
                }
            }
        }
        let nest_time = t0.elapsed().as_secs_f64();
        let alpa_tput = alpa.throughput();
        for (name, strategy, tput, solve_s) in [
            (
                "alpa-o",
                alpa.strategy(),
                alpa_tput,
                alpa.solve_seconds,
            ),
            (
                "nest",
                best.as_ref()
                    .map(|s| s.plan.strategy_string())
                    .unwrap_or_else(|| "✗".into()),
                best.as_ref()
                    .map(|s| {
                        simulate(&graph, &cluster, &s.plan, Schedule::OneFOneB).throughput
                    })
                    .unwrap_or(0.0),
                nest_time,
            ),
        ] {
            let ratio = if alpa_tput > 0.0 { tput / alpa_tput } else { 0.0 };
            tbl.row(vec![
                format!("{n}×V100"),
                name.into(),
                strategy.clone(),
                format!("{tput:.2}"),
                format!("{ratio:.2}x"),
                crate::util::table::fmt_time(solve_s),
            ]);
            csv.row(vec![
                n.to_string(),
                name.into(),
                strategy,
                tput.to_string(),
                solve_s.to_string(),
            ]);
        }
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/v100.csv", opts.results_dir));
}

/// Heterogeneous-pool table: NEST on a mixed H100+V100 pool versus the
/// same fabric with every device constrained to a single class. The
/// mixed-pool solve must be strictly faster (analytic batch time) than
/// the all-V100-constrained solve — the fast island must buy something
/// — and can of course not beat all-H100. Prints where the layers
/// landed per accelerator class; returns `false` on a regression.
pub fn hetero(opts: &HarnessOpts) -> bool {
    println!("== Heterogeneous pool: mixed H100+V100 vs single-class twins ==");
    let mixed = Cluster::hetero_pool(64);
    let model = "llama2-7b";
    let graph = models::by_name(model, 1).unwrap();
    let variants: Vec<(&str, Cluster)> = vec![
        ("mixed h100+v100", mixed.clone()),
        (
            "all v100",
            mixed.with_uniform_accel(crate::hw::Accelerator::v100()),
        ),
        (
            "all h100",
            mixed.with_uniform_accel(crate::hw::Accelerator::h100()),
        ),
    ];
    let mut tbl = Table::new(&[
        "pool",
        "strategy",
        "batch",
        "vs all-v100",
        "layers on h100",
        "layers on v100",
    ]);
    let mut csv = Csv::new(&[
        "pool",
        "strategy",
        "batch_s",
        "speedup_vs_v100",
        "layers_h100",
        "layers_v100",
    ]);
    let sols: Vec<_> = variants
        .iter()
        .map(|(label, cluster)| {
            let sol = nest_solve(&graph, cluster, &opts.solver);
            if let Some(s) = &sol {
                s.plan
                    .validate(&graph, cluster)
                    .unwrap_or_else(|e| panic!("{label}: invalid plan: {e}"));
            }
            sol
        })
        .collect();
    let v100_batch = sols[1].as_ref().map(|s| s.plan.batch_time);
    for ((label, _), sol) in variants.iter().zip(&sols) {
        let Some(sol) = sol else {
            tbl.row(vec!["✗".into(); 6]);
            continue;
        };
        // Layers per class: a stage counts toward every class its
        // lockstep device group covers (mixed stages count to both).
        let mut on_h100 = 0usize;
        let mut on_v100 = 0usize;
        for st in &sol.plan.stages {
            let layers = st.layers.1 - st.layers.0;
            if st.accel_class.contains("h100") {
                on_h100 += layers;
            }
            if st.accel_class.contains("v100") {
                on_v100 += layers;
            }
        }
        let speedup = match v100_batch {
            Some(v) if v > 0.0 => format!("{:.2}×", v / sol.plan.batch_time),
            _ => "-".into(),
        };
        tbl.row(vec![
            label.to_string(),
            sol.plan.strategy_string(),
            crate::util::table::fmt_time(sol.plan.batch_time),
            speedup.clone(),
            on_h100.to_string(),
            on_v100.to_string(),
        ]);
        csv.row(vec![
            label.to_string(),
            sol.plan.strategy_string(),
            sol.plan.batch_time.to_string(),
            speedup,
            on_h100.to_string(),
            on_v100.to_string(),
        ]);
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/hetero.csv", opts.results_dir));
    let ok = match (&sols[0], v100_batch) {
        (Some(mixed_sol), Some(v100_t)) => mixed_sol.plan.batch_time < v100_t,
        _ => false,
    };
    println!(
        "mixed pool strictly faster than the all-V100 constraint: {}",
        if ok { "✓" } else { "✗ REGRESSION" }
    );
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_opts(tag: &str) -> HarnessOpts {
        let mut o = HarnessOpts::quick();
        o.results_dir = std::env::temp_dir()
            .join(format!("nest_{tag}"))
            .to_string_lossy()
            .into_owned();
        o
    }

    #[test]
    fn table6_runs() {
        table6(&tmp_opts("t6"));
    }

    #[test]
    fn hetero_table_mixed_beats_v100_twin() {
        // The heterogeneous acceptance invariant: on the mixed pool the
        // solver strictly beats the all-V100-constrained solve.
        assert!(
            hetero(&tmp_opts("hetero")),
            "mixed pool not strictly faster than the all-V100 twin"
        );
    }

    #[test]
    fn table7_zero_unlocks_constrained_training() {
        // The core Table-7 claim as an assertion: with 120 MB devices,
        // BertLarge training is only feasible with ZeRO enabled.
        let graph = models::bert_large(1);
        let mut cluster = Cluster::fat_tree_tpuv4(1024);
        cluster.shrink_capacity(120e6);
        let with = nest_solve(&graph, &cluster, &SolverOpts::default());
        assert!(with.is_some(), "ZeRO should make 120MB feasible");
        let plan = &with.unwrap().plan;
        assert!(
            plan.stages.iter().any(|s| s.mem.zero != ZeroStage::None),
            "expected ZeRO stages, got {}",
            plan.describe()
        );
    }

    #[test]
    fn v100_exact_competitive_with_alpa() {
        // §5.4: NEST within ~7% of Alpa at 8 devices, ahead at 16.
        let graph = models::mixtral_scaled(1);
        let opts = tmp_opts("v100");
        for (n, min_ratio) in [(8usize, 0.90), (16, 1.0)] {
            let cluster = Cluster::v100_cluster(n);
            let alpa = run_method(&graph, &cluster, Method::AlpaE, &opts);
            let mut best: Option<f64> = None;
            for d in [1usize, 2, 4] {
                for rc in [false, true] {
                    if let Some(s) = solve_exact(
                        &graph,
                        &cluster,
                        &ExactOpts {
                            max_stages: 8,
                            dp_width: d,
                            recompute: rc,
                            threads: opts.solver.threads,
                            ..Default::default()
                        },
                    ) {
                        let t = simulate(&graph, &cluster, &s.plan, Schedule::OneFOneB)
                            .throughput;
                        best = Some(best.map_or(t, |b: f64| b.max(t)));
                    }
                }
            }
            let nest = best.expect("exact solver found nothing");
            let alpa_t = alpa.throughput();
            if alpa_t > 0.0 {
                assert!(
                    nest >= alpa_t * min_ratio,
                    "{n} devices: nest {nest} vs alpa {alpa_t}"
                );
            }
        }
    }
}
