//! Multi-tenant workload-mix harness: how placement rankings move when
//! the fabric is shared.
//!
//! For each topology family the solver produces the analytic top-K
//! shortlist, [`crate::solver::refine::refine_under_load`] replays it
//! under seeded background mixes ([`crate::netsim::flowgen`]) at each
//! requested max per-link load level, and the table reports — per
//! (family, level) — the analytic winner's and the robust winner's
//! *training* batch times under load, the robust winner's worst-case
//! degradation, and whether the contention-robust ranking flipped away
//! from the zero-load choice. The falsifiable gate per family: the
//! robust winner's degradation must not exceed the analytic rank-1
//! plan's (the whole point of refining under load), and every replay
//! must be finite and positive.

use crate::graph::models;
use crate::netsim::LinkGraph;
use crate::network::Cluster;
use crate::solver::refine::{refine_under_load, RefineOpts};
use crate::util::csv::Csv;
use crate::util::table::{fmt_time, Table};

use super::netsim::dumbbell_topology;
use super::HarnessOpts;

/// One topology family of the mix sweep.
struct Family {
    label: &'static str,
    cluster: Cluster,
    topo: LinkGraph,
}

fn families(quick: bool) -> Vec<Family> {
    let n = if quick { 64 } else { 128 };
    let mut out = Vec::new();
    let fat = Cluster::fat_tree_tpuv4(n);
    out.push(Family {
        label: "fat-tree",
        topo: LinkGraph::from_cluster(&fat),
        cluster: fat,
    });
    let spine = Cluster::spine_leaf_h100(n, 4.0);
    out.push(Family {
        label: "spine-leaf 4:1",
        topo: LinkGraph::from_cluster(&spine),
        cluster: spine,
    });
    let (cluster, edge) = dumbbell_topology();
    out.push(Family {
        label: "edge-list dumbbell",
        cluster,
        topo: edge,
    });
    out
}

/// The default load sweep (`nest mix` without `--bg-load`): light,
/// moderate, and heavy background traffic.
pub const DEFAULT_BG_LOADS: [f64; 3] = [0.2, 0.4, 0.6];

/// The cross-topology mix table: one row per (family, load level).
/// Returns false when a family is infeasible, a replay produced a
/// non-finite training time, or the robust winner degrades more than
/// the analytic rank-1 plan (which [`refine_under_load`] must prevent).
pub fn mix_table(opts: &HarnessOpts, bg_loads: &[f64], topk: usize, quick: bool) -> bool {
    println!(
        "== workload mixes: DP top-{topk} shortlist refined under background load ==",
    );
    let mut tbl = Table::new(&[
        "topology",
        "devices",
        "bg load",
        "dp winner under load",
        "robust winner",
        "robust under load",
        "degradation",
        "flip",
    ]);
    let mut csv = Csv::new(&[
        "topology",
        "model",
        "devices",
        "topk",
        "bg_load",
        "analytic_strategy",
        "analytic_bg_sim_s",
        "rerank_strategy",
        "rerank_bg_sim_s",
        "rerank_zero_load_sim_s",
        "analytic_vs_sim_delta_pct",
        "rerank_degradation_pct",
        "winner_changed",
        "ok",
    ]);
    let model = "llama2-7b";
    let graph = models::by_name(model, 1).expect("model exists");
    let mut all_ok = true;
    let mut any_flip = false;
    for fam in families(quick) {
        let ropts = RefineOpts {
            topk,
            netsim: opts.netsim,
            bg_loads: bg_loads.to_vec(),
            ..Default::default()
        };
        let Some(rep) = refine_under_load(&graph, &fam.cluster, &fam.topo, &opts.solver, &ropts)
        else {
            tbl.row(vec![
                fam.label.into(),
                fam.cluster.n_devices().to_string(),
                "-".into(),
                "✗".into(),
                "✗".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            all_ok = false;
            continue;
        };
        let ana = rep.analytic_winner();
        let win = rep.winner();
        // The falsifiable family gate: refining under load must never
        // pick a plan that degrades *more* than the analytic rank-1,
        // and every replay must produce a sane training time.
        let ok = win.degradation <= ana.degradation
            && rep
                .ranked
                .iter()
                .flat_map(|r| r.bg_sim.iter())
                .all(|&t| t.is_finite() && t > 0.0);
        all_ok &= ok;
        any_flip |= rep.winner_changed();
        for (li, &load) in rep.bg_loads.iter().enumerate() {
            let delta = (win.bg_sim[li] - win.analytic_batch) / win.analytic_batch;
            tbl.row(vec![
                fam.label.into(),
                fam.cluster.n_devices().to_string(),
                format!("{:.0}%", load * 100.0),
                fmt_time(ana.bg_sim[li]),
                win.plan.strategy_string(),
                fmt_time(win.bg_sim[li]),
                format!("{:+.1}%", win.degradation * 100.0),
                if rep.winner_changed() {
                    format!("FLIP {}", if ok { "✓" } else { "✗" })
                } else {
                    "no".into()
                },
            ]);
            csv.row(vec![
                fam.label.into(),
                model.into(),
                fam.cluster.n_devices().to_string(),
                topk.to_string(),
                load.to_string(),
                ana.plan.strategy_string(),
                ana.bg_sim[li].to_string(),
                win.plan.strategy_string(),
                win.bg_sim[li].to_string(),
                win.sim_batch.to_string(),
                (delta * 100.0).to_string(),
                (win.degradation * 100.0).to_string(),
                rep.winner_changed().to_string(),
                ok.to_string(),
            ]);
        }
    }
    println!("{}", tbl.render());
    println!(
        "robust winner degrades no more than the analytic rank-1 on every family: {}",
        if all_ok { "✓" } else { "✗ REGRESSION (or infeasible family)" }
    );
    if any_flip {
        println!(
            "≥ 1 topology picked a different winner under background load — \
             contention-robust refinement is live"
        );
    } else {
        println!("no ranking flips under background load on this sweep");
    }
    let _ = csv.write(format!("{}/mix.csv", opts.results_dir));
    all_ok
}

/// Deterministic mix snapshot of the shipped dumbbell edge-list
/// (llama2-7b, serial solver, fixed load levels): the golden-file suite
/// pins this rendered shortlist to catch silent drift in the flowgen
/// draw, the injection path, or the degradation ranking. Every cell is
/// a pure function of the inputs — no wall-clock, no thread count.
pub fn mix_snapshot() -> String {
    let (cluster, topo) = dumbbell_topology();
    let graph = models::by_name("llama2-7b", 1).expect("model exists");
    let sopts = crate::solver::SolverOpts {
        threads: 1,
        ..Default::default()
    };
    let ropts = RefineOpts {
        topk: 2,
        bg_loads: vec![0.3, 0.6],
        ..Default::default()
    };
    let rep = refine_under_load(&graph, &cluster, &topo, &sopts, &ropts)
        .expect("dumbbell solvable");
    rep.render_table()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_table_runs_and_gate_holds() {
        let mut opts = HarnessOpts::quick();
        opts.results_dir = std::env::temp_dir()
            .join("nest_mix_table")
            .to_string_lossy()
            .into_owned();
        assert!(
            mix_table(&opts, &DEFAULT_BG_LOADS, 2, true),
            "robust winner degraded more than the analytic rank-1 on a family"
        );
        let csv = std::fs::read_to_string(format!("{}/mix.csv", opts.results_dir))
            .expect("mix.csv written");
        // One row per (family, level) plus the header.
        assert_eq!(csv.lines().count(), 1 + 3 * DEFAULT_BG_LOADS.len());
    }

    #[test]
    fn mix_snapshot_is_stable_across_calls() {
        assert_eq!(mix_snapshot(), mix_snapshot());
    }
}
