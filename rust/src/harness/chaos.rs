//! Chaos harness: how placements and the placement service survive
//! injected faults.
//!
//! For each topology family the solver produces the analytic top-K
//! shortlist, [`crate::solver::refine::refine_under_load`] replays it
//! under N seeded fault scenarios ([`crate::netsim::faults`]) per
//! severity level — link kills, brownouts, flap windows, and device
//! stragglers — and the survival table reports, per (family, severity),
//! the analytic winner's and the fault-aware winner's throughput
//! retention (clean simulated batch time over the level's worst-case
//! faulted time) plus whether [`crate::service::PlacementService::reconcile`]
//! still produces a valid plan when the same severity is expressed as
//! failed devices. The falsifiable gate per family: the fault-aware
//! winner must never retain less throughput under faults than the
//! analytic rank-1 plan (the whole point of the fault axis), every
//! faulted replay must be finite and no faster than the clean one, and
//! reconcile must answer every severity with a plan — degraded is fine,
//! an error is not.

use crate::graph::models;
use crate::netsim::LinkGraph;
use crate::network::Cluster;
use crate::service::{ClusterDelta, PlacementService, Query};
use crate::solver::refine::{refine_under_load, RefineOpts};
use crate::util::csv::Csv;
use crate::util::table::Table;

use super::netsim::dumbbell_topology;
use super::HarnessOpts;

/// One topology family of the chaos sweep (the mix harness's families,
/// shared so the two tables describe the same fabrics).
struct Family {
    label: &'static str,
    cluster: Cluster,
    topo: LinkGraph,
}

fn families(quick: bool) -> Vec<Family> {
    let n = if quick { 64 } else { 128 };
    let mut out = Vec::new();
    let fat = Cluster::fat_tree_tpuv4(n);
    out.push(Family {
        label: "fat-tree",
        topo: LinkGraph::from_cluster(&fat),
        cluster: fat,
    });
    let spine = Cluster::spine_leaf_h100(n, 4.0);
    out.push(Family {
        label: "spine-leaf 4:1",
        topo: LinkGraph::from_cluster(&spine),
        cluster: spine,
    });
    let (cluster, edge) = dumbbell_topology();
    out.push(Family {
        label: "edge-list dumbbell",
        cluster,
        topo: edge,
    });
    out
}

/// The default severity sweep (`nest chaos` without `--fault-severity`):
/// mild, moderate, and heavy fault pressure.
pub const DEFAULT_FAULT_SEVERITIES: [f64; 3] = [0.3, 0.6, 0.9];

/// The severity expressed as failed devices: ids spanning
/// `ceil(severity · outer_arity)` outermost groups, capped at half the
/// groups so a quorum always survives (the survival table measures the
/// service's answer under losses it *should* absorb, not capacity
/// planning at one device) — [`ClusterDelta::FailDevices`] quantizes
/// each id to its whole group.
fn failed_ids(cluster: &Cluster, severity: f64) -> Vec<usize> {
    let n = cluster.n_devices();
    let outer = cluster.tiers.last().map(|t| t.arity).unwrap_or(1).max(2);
    let per_group = (n / outer).max(1);
    let groups = ((severity * outer as f64).ceil() as usize).clamp(1, (outer / 2).max(1));
    (0..groups).map(|g| g * per_group).collect()
}

/// The cross-topology survival table: one row per (family, severity).
/// Returns false when a family is infeasible, a faulted replay produced
/// a non-finite (or faster-than-clean) training time, the fault-aware
/// winner retains less throughput than the analytic rank-1 plan, or
/// reconcile errors on the severity's failed-device delta.
pub fn chaos_table(
    opts: &HarnessOpts,
    severities: &[f64],
    scenarios: usize,
    seed: u64,
    topk: usize,
    quick: bool,
) -> bool {
    println!(
        "== chaos: DP top-{topk} shortlist replayed under {scenarios} seeded fault \
         scenario(s) per severity ==",
    );
    let mut tbl = Table::new(&[
        "topology",
        "devices",
        "severity",
        "dp retention",
        "robust retention",
        "robust winner",
        "flip",
        "reconcile",
    ]);
    let mut csv = Csv::new(&[
        "topology",
        "model",
        "devices",
        "topk",
        "severity",
        "scenarios",
        "analytic_retention",
        "robust_retention",
        "robust_strategy",
        "winner_changed",
        "reconcile_ok",
        "reconcile_degraded",
        "concessions",
        "ok",
    ]);
    let model = "llama2-7b";
    let graph = models::by_name(model, 1).expect("model exists");
    let mut all_ok = true;
    let mut any_flip = false;
    for fam in families(quick) {
        let ropts = RefineOpts {
            topk,
            netsim: opts.netsim,
            fault_severities: severities.to_vec(),
            fault_scenarios: scenarios,
            fault_seed: seed,
            ..Default::default()
        };
        let Some(rep) = refine_under_load(&graph, &fam.cluster, &fam.topo, &opts.solver, &ropts)
        else {
            tbl.row(vec![
                fam.label.into(),
                fam.cluster.n_devices().to_string(),
                "-".into(),
                "✗".into(),
                "✗".into(),
                "✗".into(),
                "-".into(),
                "-".into(),
            ]);
            all_ok = false;
            continue;
        };
        let ana = rep.analytic_winner();
        let win = rep.winner();
        // The falsifiable family gate: the fault-aware winner never
        // retains less than the analytic rank-1 under the ranking key,
        // and faults only ever slow a replay down.
        let sane = rep.ranked.iter().all(|r| {
            r.fault_sim
                .iter()
                .all(|&t| t.is_finite() && t >= r.sim_batch * (1.0 - 1e-9))
        });
        let mut ok = sane && win.retention >= ana.retention;
        any_flip |= rep.winner_changed();

        // The same severities as failed devices through the service: a
        // fresh service per family, one reconcile per severity.
        let mut svc = PlacementService::new(8);
        for (li, &sev) in rep.fault_severities.iter().enumerate() {
            let query = Query::new(graph.clone(), fam.cluster.clone(), opts.solver.clone());
            let delta = ClusterDelta::FailDevices {
                ids: failed_ids(&fam.cluster, sev),
            };
            let outcome = svc.reconcile(&query, &delta);
            let (rec_ok, rec_degraded, concessions, rec_cell) = match &outcome {
                Ok(o) => (
                    o.report().plan.validate(&graph, &o.report().cluster).is_ok(),
                    o.degraded(),
                    o.concessions().len(),
                    if o.degraded() {
                        format!("degraded ({})", o.concessions().len())
                    } else {
                        "clean".into()
                    },
                ),
                Err(e) => (false, false, 0, format!("✗ {e}")),
            };
            ok &= rec_ok;
            let ana_ret = ana.sim_batch / ana.fault_sim[li];
            let win_ret = win.sim_batch / win.fault_sim[li];
            tbl.row(vec![
                fam.label.into(),
                fam.cluster.n_devices().to_string(),
                format!("{:.0}%", sev * 100.0),
                format!("{:.0}%", ana_ret * 100.0),
                format!("{:.0}%", win_ret * 100.0),
                win.plan.strategy_string(),
                if rep.winner_changed() {
                    format!("FLIP {}", if ok { "✓" } else { "✗" })
                } else {
                    "no".into()
                },
                rec_cell,
            ]);
            csv.row(vec![
                fam.label.into(),
                model.into(),
                fam.cluster.n_devices().to_string(),
                topk.to_string(),
                sev.to_string(),
                scenarios.to_string(),
                ana_ret.to_string(),
                win_ret.to_string(),
                win.plan.strategy_string(),
                rep.winner_changed().to_string(),
                rec_ok.to_string(),
                rec_degraded.to_string(),
                concessions.to_string(),
                ok.to_string(),
            ]);
        }
        all_ok &= ok;
    }
    println!("{}", tbl.render());
    println!(
        "fault-aware winner retains at least the analytic rank-1's throughput and \
         reconcile survived every severity on every family: {}",
        if all_ok {
            "✓"
        } else {
            "✗ REGRESSION (or infeasible family)"
        }
    );
    if any_flip {
        println!(
            "≥ 1 topology picked a different winner under faults — \
             failure-robust refinement is live"
        );
    } else {
        println!("no ranking flips under faults on this sweep");
    }
    let _ = csv.write(format!("{}/chaos.csv", opts.results_dir));
    all_ok
}

/// Deterministic chaos snapshot of the shipped dumbbell edge-list
/// (llama2-7b, serial solver, fixed severities and fault seed): the
/// golden-file suite pins this rendered shortlist to catch silent drift
/// in the fault draw, the capacity-event injection, the straggler
/// lowering, or the retention ranking. Every cell is a pure function of
/// the inputs — no wall-clock, no thread count.
pub fn chaos_snapshot() -> String {
    let (cluster, topo) = dumbbell_topology();
    let graph = models::by_name("llama2-7b", 1).expect("model exists");
    let sopts = crate::solver::SolverOpts {
        threads: 1,
        ..Default::default()
    };
    let ropts = RefineOpts {
        topk: 2,
        fault_severities: vec![0.3, 0.7],
        fault_scenarios: 2,
        ..Default::default()
    };
    let rep = refine_under_load(&graph, &cluster, &topo, &sopts, &ropts)
        .expect("dumbbell solvable");
    rep.render_table()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_table_runs_and_gate_holds() {
        let mut opts = HarnessOpts::quick();
        opts.results_dir = std::env::temp_dir()
            .join("nest_chaos_table")
            .to_string_lossy()
            .into_owned();
        assert!(
            chaos_table(&opts, &[0.4, 0.8], 1, 0xFA17, 2, true),
            "fault-aware winner retained less than the analytic rank-1 (or reconcile failed)"
        );
        let csv = std::fs::read_to_string(format!("{}/chaos.csv", opts.results_dir))
            .expect("chaos.csv written");
        // One row per (family, severity) plus the header.
        assert_eq!(csv.lines().count(), 1 + 3 * 2);
        // Reconcile answered every row.
        for line in csv.lines().skip(1) {
            assert!(line.contains(",true,"), "reconcile failed in: {line}");
        }
    }

    #[test]
    fn chaos_snapshot_is_stable_across_calls() {
        let a = chaos_snapshot();
        assert_eq!(a, chaos_snapshot());
        assert!(a.contains("faults 30%") && a.contains("faults 70%"));
        assert!(a.contains("retention"));
    }

    #[test]
    fn failed_ids_scale_with_severity_and_spare_a_group() {
        let c = Cluster::fat_tree_tpuv4(64);
        let outer = c.tiers.last().unwrap().arity;
        for sev in [0.1, 0.5, 1.0] {
            let ids = failed_ids(&c, sev);
            assert!(!ids.is_empty());
            let delta = ClusterDelta::FailDevices { ids };
            let after = delta.apply(&c).expect("always leaves a group standing");
            assert!(after.n_devices() < c.n_devices());
            assert!(after.tiers.last().unwrap().arity < outer || outer == 1);
        }
    }
}
