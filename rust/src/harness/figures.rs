//! Figure harnesses: Figures 2, 5, 6, 7, 10, 11.

use super::{geomean_speedup, run_method, run_methods, HarnessOpts, Method};
use crate::baselines::{build_plan, even_cuts};
use crate::graph::models;
use crate::graph::subgraph::SgConfig;
use crate::network::Cluster;
use crate::sim::{simulate, Schedule};
use crate::util::csv::Csv;
use crate::util::table::Table;

/// Figure 2: impact of communication latency across parallelism
/// strategies on a 2:2 oversubscribed 64-GPU H100 cluster, with and
/// without activation recomputation. Prints per-strategy iteration time
/// and communication share.
pub fn figure2(opts: &HarnessOpts) {
    println!("== Figure 2: communication impact across strategies (64×H100, 2:2 oversubscribed) ==");
    let cluster = Cluster::spine_leaf_h100(64, 2.0);
    let mut csv = Csv::new(&[
        "model", "strategy", "recompute", "batch_time_s", "comm_frac",
    ]);
    let mut tbl = Table::new(&["model", "strategy", "AR", "batch time", "comm %"]);

    for (model, variants) in [
        (
            "gpt3-175b",
            vec![("TP8-PP8", 8usize, 8usize, 1usize), ("TP4-PP16", 16, 4, 1), ("TP8-DP", 2, 8, 1), ("PP32", 32, 1, 1)],
        ),
        (
            "llama3-70b",
            vec![("PP80", 80, 1, 1), ("PP40-DP", 40, 1, 1), ("PP16-DP", 16, 1, 1), ("PP8-DP", 8, 1, 1)],
        ),
        (
            "mixtral-8x7b",
            vec![("EP4-PP8", 8, 1, 4), ("EP8-PP4", 4, 1, 8), ("EP4-PP16", 16, 1, 4), ("PP32", 32, 1, 1)],
        ),
    ] {
        let graph = models::by_name(model, 1).unwrap();
        for (name, p, t, e) in variants {
            let sg = SgConfig {
                tp: t,
                sp: t > 1,
                ep: e,
                cp: 1,
            };
            let g = sg.group_size();
            let p = p.min(graph.n_layers()).min(64 / g);
            let d = (64 / (p * g)).max(1);
            for rc in [false, true] {
                let cuts = even_cuts(graph.n_layers(), p);
                let Some(plan) = build_plan(&graph, &cluster, "fixed", sg, &cuts, d, rc, 8)
                else {
                    tbl.row(vec![
                        model.into(),
                        name.into(),
                        if rc { "yes" } else { "no" }.into(),
                        "✗ (OOM)".into(),
                        "-".into(),
                    ]);
                    continue;
                };
                let sim = simulate(&graph, &cluster, &plan, Schedule::OneFOneB);
                tbl.row(vec![
                    model.into(),
                    name.into(),
                    if rc { "yes" } else { "no" }.into(),
                    crate::util::table::fmt_time(sim.batch_time),
                    format!("{:.1}%", sim.comm_fraction * 100.0),
                ]);
                csv.row(vec![
                    model.into(),
                    name.into(),
                    rc.to_string(),
                    sim.batch_time.to_string(),
                    sim.comm_fraction.to_string(),
                ]);
            }
        }
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/figure2.csv", opts.results_dir));
}

/// Shared scaffolding for the Figure 5 / Figure 7 throughput sweeps.
fn throughput_sweep(
    title: &str,
    csv_name: &str,
    cluster_of: impl Fn(usize) -> Cluster,
    sizes: &[usize],
    model_names: &[&str],
    methods: &[Method],
    opts: &HarnessOpts,
) {
    println!("== {title} ==");
    let mut csv = Csv::new(&["model", "devices", "method", "throughput", "relative", "strategy"]);
    // (nest, baseline) throughput pairs per baseline for the headline
    // geomean aggregates.
    let mut pairs: std::collections::BTreeMap<&'static str, Vec<(f64, f64)>> =
        Default::default();

    for model in model_names {
        let graph = models::by_name(model, 1).unwrap();
        let mut header = vec!["devices"];
        header.extend(methods.iter().map(|m| m.name()));
        let mut tbl = Table::new(&header);
        // Normalization: manual baseline's smallest valid result.
        let mut manual_ref: Option<f64> = None;
        for &n in sizes {
            let cluster = cluster_of(n);
            let results = run_methods(&graph, &cluster, methods, opts);
            if manual_ref.is_none() {
                manual_ref = results
                    .iter()
                    .find(|r| r.method == Method::Manual && r.throughput() > 0.0)
                    .map(|r| r.throughput());
            }
            let nest_tput = results
                .iter()
                .find(|r| r.method == Method::Nest)
                .map(|r| r.throughput())
                .unwrap_or(0.0);
            let mut row = vec![n.to_string()];
            for r in &results {
                let rel = manual_ref
                    .map(|m| r.throughput() / m)
                    .unwrap_or(0.0);
                row.push(if r.throughput() > 0.0 {
                    format!("{rel:.2}x")
                } else {
                    "✗".into()
                });
                csv.row(vec![
                    model.to_string(),
                    n.to_string(),
                    r.method.name().into(),
                    r.throughput().to_string(),
                    rel.to_string(),
                    r.strategy(),
                ]);
                if r.method != Method::Nest && r.throughput() > 0.0 && nest_tput > 0.0 {
                    pairs
                        .entry(r.method.name())
                        .or_default()
                        .push((nest_tput, r.throughput()));
                }
            }
            tbl.row(row);
        }
        println!("-- {model} (relative to manual's smallest valid result) --");
        println!("{}", tbl.render());
    }
    println!("Headline aggregates (geomean NEST speedup):");
    for (name, ps) in &pairs {
        println!("  vs {:8} {:.2}x (n={})", name, geomean_speedup(ps), ps.len());
    }
    let _ = csv.write(format!("{}/{csv_name}.csv", opts.results_dir));
}

/// Figure 5: throughput vs baselines on fat-tree TPUv4, 64–1024 devices.
pub fn figure5(opts: &HarnessOpts, sizes: &[usize]) {
    throughput_sweep(
        "Figure 5: fat-tree TPUv4 throughput (relative to manual)",
        "figure5",
        Cluster::fat_tree_tpuv4,
        sizes,
        &["bertlarge", "llama2-7b", "llama3-70b", "gpt3-175b", "mixtral-8x7b"],
        &[Method::Manual, Method::Mcmc, Method::Phaze, Method::AlpaE, Method::Nest],
        opts,
    );
}

/// Figure 7: spine-leaf 1024×H100 (2:2 oversubscribed) with Mist.
pub fn figure7(opts: &HarnessOpts, n_devices: usize) {
    throughput_sweep(
        "Figure 7: spine-leaf H100 throughput (relative to manual)",
        "figure7",
        |n| Cluster::spine_leaf_h100(n, 2.0),
        &[n_devices],
        &[
            "bertlarge", "llama2-7b", "llama3-70b", "gpt3-35b", "gpt3-175b", "mixtral-8x7b",
        ],
        &[Method::Manual, Method::Mcmc, Method::Phaze, Method::Mist, Method::Nest],
        opts,
    );
}

/// Figures 6 / 11: joint microbatch-size exploration at a fixed cluster
/// size (256 for Fig. 6, 512 for Fig. 11). Throughput relative to the
/// manual baseline at microbatch size 1.
pub fn microbatch_sweep(opts: &HarnessOpts, n_devices: usize, csv_name: &str) {
    println!("== Microbatch sweep on {n_devices} TPUv4 (Figure {}) ==",
             if n_devices == 256 { "6" } else { "11" });
    let cluster = Cluster::fat_tree_tpuv4(n_devices);
    let methods = [Method::Manual, Method::Phaze, Method::AlpaE, Method::Nest];
    let mut csv = Csv::new(&["model", "mbs", "method", "throughput", "relative", "strategy"]);

    for model in ["bertlarge", "llama2-7b", "llama3-70b"] {
        let mut header = vec!["mbs"];
        header.extend(methods.iter().map(|m| m.name()));
        let mut tbl = Table::new(&header);
        // Reference: manual at mbs 1.
        let g1 = models::by_name(model, 1).unwrap();
        let manual_ref = run_method(&g1, &cluster, Method::Manual, opts).throughput();
        for mbs in [1usize, 2, 4, 8] {
            let graph = models::by_name(model, mbs).unwrap();
            let mut row = vec![mbs.to_string()];
            for &m in &methods {
                let r = run_method(&graph, &cluster, m, opts);
                let rel = if manual_ref > 0.0 {
                    r.throughput() / manual_ref
                } else {
                    0.0
                };
                row.push(if r.throughput() > 0.0 {
                    format!("{rel:.2}x")
                } else {
                    "✗".into()
                });
                csv.row(vec![
                    model.to_string(),
                    mbs.to_string(),
                    m.name().into(),
                    r.throughput().to_string(),
                    rel.to_string(),
                    r.strategy(),
                ]);
            }
            tbl.row(row);
        }
        println!("-- {model} (relative to manual @ mbs 1) --");
        println!("{}", tbl.render());
    }
    let _ = csv.write(format!("{}/{csv_name}.csv", opts.results_dir));
}

// ---------------------------------------------------------------------------
// Figure 10: collective-communication model validation.
// ---------------------------------------------------------------------------

/// Message-level discrete simulation of a hierarchical ring all-reduce —
/// the referee the α–β closed form (network::collectives) is validated
/// against (the paper validates against real H100 nodes; Fig. 10 shows
/// ≤2% error). Unlike the closed form, the DES transfers *quantized
/// messages*: each ring step ships ⌈payload / MSG_BYTES⌉ wire messages,
/// each carrying a protocol header, and each step pays the link latency
/// explicitly. The closed form's error against this referee is the
/// quantization + header cost it abstracts away — large payloads
/// converge, small payloads diverge, exactly the regime structure real
/// collectives show.
pub fn des_allreduce(cluster: &Cluster, bytes: f64, shape: &[usize]) -> f64 {
    /// NCCL-like maximum wire-message size.
    const MSG_BYTES: f64 = 256.0 * 1024.0;
    /// Per-message protocol/header overhead in byte-equivalents.
    const HEADER_BYTES: f64 = 512.0;

    let mut t = 0.0f64;
    let mut shard = bytes;
    for (i, &gi) in shape.iter().enumerate() {
        if gi <= 1 {
            continue;
        }
        let tier = i.min(cluster.n_levels() - 1);
        let lat = cluster.tiers[tier].latency;
        let bw = cluster.bw_eff(tier);
        // Per ring step each participant ships shard/gi bytes split into
        // MSG_BYTES messages (headers repeat per message; payloads are
        // not padded); reduce-scatter then all-gather.
        let payload = shard / gi as f64;
        let n_msgs = (payload / MSG_BYTES).ceil().max(1.0);
        let wire_bytes = payload + n_msgs * HEADER_BYTES;
        let step_time = wire_bytes / bw + lat;
        t += 2.0 * (gi as f64 - 1.0) * step_time;
        shard /= gi as f64;
    }
    t
}

/// Figure 10: analytical collective estimates vs the chunk-level DES,
/// plus measured-vs-predicted probe runtimes from the PJRT profiler when
/// artifacts are present.
pub fn figure10(opts: &HarnessOpts) {
    println!("== Figure 10: collective estimate validation ==");
    let cluster = Cluster::spine_leaf_h100(64, 2.0);
    let mut tbl = Table::new(&["group", "payload", "analytical", "DES", "error"]);
    let mut csv = Csv::new(&["group", "bytes", "analytical_s", "des_s", "rel_error"]);
    let mut worst: f64 = 0.0;
    for g in [4usize, 8, 16, 32] {
        let shape = cluster.compact_shape(g);
        for bytes in [1e6, 1e7, 1e8, 1e9] {
            let analytical = cluster.allreduce(bytes, &shape);
            let des = des_allreduce(&cluster, bytes, &shape);
            let err = (analytical - des).abs() / des;
            worst = worst.max(err);
            tbl.row(vec![
                format!("{g} ({shape:?})"),
                crate::util::table::fmt_bytes(bytes),
                crate::util::table::fmt_time(analytical),
                crate::util::table::fmt_time(des),
                format!("{:.2}%", err * 100.0),
            ]);
            csv.row(vec![
                g.to_string(),
                bytes.to_string(),
                analytical.to_string(),
                des.to_string(),
                err.to_string(),
            ]);
        }
    }
    println!("{}", tbl.render());
    println!("worst-case analytical-vs-DES error: {:.2}% (paper reports ≤2% vs H100)", worst * 100.0);

    // Part 2: measured PJRT probe runtimes vs the calibrated roofline.
    if let Some(dir) = crate::runtime::artifacts_dir() {
        match crate::profiler::calibrate(&dir, 5) {
            Ok(cal) => {
                let mut t2 = Table::new(&["probe", "measured", "predicted", "error"]);
                for p in &cal.probes {
                    let predicted = p.flops / cal.accel.achieved_matmul();
                    let err = (predicted - p.median_seconds).abs() / p.median_seconds;
                    t2.row(vec![
                        format!("block h={}", p.hidden),
                        crate::util::table::fmt_time(p.median_seconds),
                        crate::util::table::fmt_time(predicted),
                        format!("{:.1}%", err * 100.0),
                    ]);
                }
                println!("-- measured (PJRT CPU) vs calibrated roofline --");
                println!("{}", t2.render());
            }
            Err(e) => eprintln!("probe calibration failed: {e:#}"),
        }
    } else {
        println!("(run `make artifacts` for the measured-probe half of Fig. 10)");
    }
    let _ = csv.write(format!("{}/figure10.csv", opts.results_dir));
}

/// Appendix B.2: torus/mesh evaluation via the level-wise abstraction.
/// Solves the Table-2 models on a 2D-torus TPU pod and a fat-tree of the
/// same size, showing the same DP adapts across topology families (the
/// paper's "topology-agnostic" claim, §4 Key Observation).
pub fn torus(opts: &HarnessOpts, n_devices: usize) {
    println!("== Appendix B.2: torus vs fat-tree placement ({n_devices} devices) ==");
    let side = (n_devices as f64).sqrt() as usize;
    let torus = Cluster::torus2d(side, n_devices / side, 50.0 * 1e9, 1e-6);
    let fat = Cluster::fat_tree_tpuv4(n_devices);
    let mut tbl = Table::new(&[
        "model", "torus strategy", "torus tput", "fat-tree strategy", "fat-tree tput",
    ]);
    let mut csv = Csv::new(&["model", "cluster", "strategy", "throughput"]);
    for model in ["llama2-7b", "gpt3-175b", "mixtral-8x7b"] {
        let graph = models::by_name(model, 1).unwrap();
        let mut cells = Vec::new();
        for c in [&torus, &fat] {
            let r = run_method(&graph, c, Method::Nest, opts);
            csv.row(vec![
                model.into(),
                c.name.clone(),
                r.strategy(),
                r.throughput().to_string(),
            ]);
            cells.push((r.strategy(), r.throughput()));
        }
        tbl.row(vec![
            model.into(),
            cells[0].0.clone(),
            format!("{:.1}/s", cells[0].1),
            cells[1].0.clone(),
            format!("{:.1}/s", cells[1].1),
        ]);
    }
    println!("{}", tbl.render());
    let _ = csv.write(format!("{}/torus.csv", opts.results_dir));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn des_allreduce_close_to_analytical_flat() {
        // Within a node (single tier, no oversubscription) the closed
        // form must track the message-level referee to within the
        // header/quantization cost it abstracts (~2%, the paper's Fig.10
        // tolerance band).
        let c = Cluster::fat_tree_tpuv4(64);
        let shape = vec![8usize];
        for bytes in [1e6, 1e8] {
            let a = c.allreduce(bytes, &shape);
            let d = des_allreduce(&c, bytes, &shape);
            assert!(
                (a - d).abs() / d < 0.03,
                "bytes={bytes}: analytical {a} vs DES {d}"
            );
            // The closed form is optimistic (no headers): a ≤ d.
            assert!(a <= d, "closed form should lower-bound the referee");
        }
    }

    #[test]
    fn des_allreduce_hierarchical_within_tolerance() {
        let c = Cluster::spine_leaf_h100(64, 2.0);
        for g in [8usize, 32] {
            let shape = c.compact_shape(g);
            let a = c.allreduce(1e8, &shape);
            let d = des_allreduce(&c, 1e8, &shape);
            assert!(
                (a - d).abs() / d < 0.10,
                "g={g}: analytical {a} vs DES {d}"
            );
        }
    }

    #[test]
    fn figure2_runs_quickly() {
        // Smoke: the harness completes and writes its CSV.
        let mut opts = HarnessOpts::quick();
        opts.results_dir = std::env::temp_dir()
            .join("nest_fig2")
            .to_string_lossy()
            .into_owned();
        figure2(&opts);
        assert!(std::path::Path::new(&opts.results_dir)
            .join("figure2.csv")
            .exists());
    }
}
