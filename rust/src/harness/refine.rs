//! Refinement-loop harness: where (and by how much) the flow-level
//! re-ranking disagrees with the analytic DP winner, across topology
//! families.
//!
//! For each family the solver produces the analytic top-K shortlist,
//! [`crate::solver::refine`] re-scores every shortlisted plan on the
//! family's explicit link graph, and the table reports the analytic
//! winner vs the re-ranked winner side by side. On uncontended fabrics
//! the two should coincide; on oversubscribed trunks and shared
//! bottleneck links the re-ranking is where the simulator graduates
//! from validation tool to decision-maker. On contended families the
//! harness re-checks netsim's core invariant for *every shortlisted
//! plan* — the flow sim must never undercut the analytic DES (the
//! abstraction can only hide congestion) — and prints a ✓/✗ verdict per
//! row. (The re-ranked winner being sim-fastest holds by construction;
//! the per-plan cross-check is the falsifiable part.)

use crate::graph::models;
use crate::netsim::LinkGraph;
use crate::network::Cluster;
use crate::sim::{simulate, Schedule};
use crate::solver::refine::refine_opts;
use crate::util::csv::Csv;
use crate::util::table::{fmt_time, Table};

use super::netsim::dumbbell_topology;
use super::HarnessOpts;

/// One topology family of the refinement sweep.
struct Family {
    label: &'static str,
    /// Whether the fabric has contention the analytic model cannot
    /// price — where ranking flips are expected to concentrate.
    contended: bool,
    cluster: Cluster,
    topo: LinkGraph,
}

fn families(quick: bool) -> Vec<Family> {
    let n = if quick { 64 } else { 128 };
    let mut out = Vec::new();
    let fat = Cluster::fat_tree_tpuv4(n);
    out.push(Family {
        label: "fat-tree",
        contended: false,
        topo: LinkGraph::from_cluster(&fat),
        cluster: fat,
    });
    let spine = Cluster::spine_leaf_h100(n, 4.0);
    out.push(Family {
        label: "spine-leaf 4:1",
        contended: true,
        topo: LinkGraph::from_cluster(&spine),
        cluster: spine,
    });
    let (cluster, edge) = dumbbell_topology();
    out.push(Family {
        label: "edge-list dumbbell",
        contended: true,
        cluster,
        topo: edge,
    });
    out
}

/// The cross-topology refinement table: one row per family. Returns
/// false when a family is infeasible or when, on a contended family,
/// any shortlisted plan's flow-sim batch time undercuts its analytic
/// DES evaluation (netsim's ≥-invariant, per plan).
pub fn refine_table(opts: &HarnessOpts, topk: usize, quick: bool) -> bool {
    println!("== refinement loop: DP top-{topk} shortlist re-ranked by the flow simulator ==");
    let mut tbl = Table::new(&[
        "topology",
        "model",
        "devices",
        "dp winner",
        "dp winner sim",
        "re-ranked winner",
        "re-rank sim",
        "sim gain",
        "flip",
    ]);
    let mut csv = Csv::new(&[
        "topology",
        "model",
        "devices",
        "topk",
        "analytic_strategy",
        "analytic_winner_sim_s",
        "rerank_strategy",
        "rerank_sim_s",
        "sim_improvement_pct",
        "winner_changed",
        "contended",
        "ok",
    ]);
    let model = "llama2-7b";
    let graph = models::by_name(model, 1).expect("model exists");
    let mut all_ok = true;
    let mut any_flip = false;
    for fam in families(quick) {
        let Some(rep) =
            refine_opts(&graph, &fam.cluster, &fam.topo, &opts.solver, topk, opts.netsim)
        else {
            tbl.row(vec![
                fam.label.into(),
                model.into(),
                fam.cluster.n_devices().to_string(),
                "✗".into(),
                "-".into(),
                "✗".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            all_ok = false;
            continue;
        };
        let ana = rep.analytic_winner();
        let win = rep.winner();
        // Falsifiable invariant (the re-ranked winner being sim-fastest
        // holds by construction): on contended fabrics, no shortlisted
        // plan's flow sim may undercut its analytic DES evaluation.
        let ok = !fam.contended
            || rep.ranked.iter().all(|r| {
                let des = simulate(&graph, &fam.cluster, &r.plan, Schedule::OneFOneB);
                r.sim_batch >= des.batch_time * (1.0 - 1e-9)
            });
        all_ok &= ok;
        any_flip |= rep.winner_changed();
        tbl.row(vec![
            fam.label.into(),
            model.into(),
            fam.cluster.n_devices().to_string(),
            ana.plan.strategy_string(),
            fmt_time(ana.sim_batch),
            win.plan.strategy_string(),
            fmt_time(win.sim_batch),
            format!("{:+.1}%", rep.sim_improvement() * 100.0),
            if rep.winner_changed() {
                format!("FLIP {}", if ok { "✓" } else { "✗" })
            } else {
                "no".into()
            },
        ]);
        csv.row(vec![
            fam.label.into(),
            model.into(),
            fam.cluster.n_devices().to_string(),
            topk.to_string(),
            ana.plan.strategy_string(),
            ana.sim_batch.to_string(),
            win.plan.strategy_string(),
            win.sim_batch.to_string(),
            (rep.sim_improvement() * 100.0).to_string(),
            rep.winner_changed().to_string(),
            fam.contended.to_string(),
            ok.to_string(),
        ]);
    }
    println!("{}", tbl.render());
    println!(
        "flow sim ≥ analytic DES for every shortlisted plan on contended rows: {}",
        if all_ok { "✓" } else { "✗ REGRESSION (or infeasible family)" }
    );
    if any_flip {
        println!(
            "≥ 1 topology re-ranked to a different (simulated-faster) winner — \
             the analytic→simulated loop is live"
        );
    } else {
        println!("no ranking flips at K={topk} on this sweep");
    }
    let _ = csv.write(format!("{}/refine.csv", opts.results_dir));
    all_ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refine_table_runs_and_invariant_holds() {
        let mut opts = HarnessOpts::quick();
        opts.results_dir = std::env::temp_dir()
            .join("nest_refine_table")
            .to_string_lossy()
            .into_owned();
        assert!(
            refine_table(&opts, 3, true),
            "a shortlisted plan's flow sim undercut its analytic DES on a contended family"
        );
    }
}
