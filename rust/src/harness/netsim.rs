//! Netsim cross-validation harness: analytic DES vs flow-level
//! simulation across topology families.
//!
//! For each family the NEST solver produces a plan against the analytic
//! abstraction, the shared DES evaluates it (`crate::sim`), and the
//! flow-level simulator replays the same batch on the explicit link
//! graph (`crate::netsim`). The table reports the batch-time error
//! between the two — the level-wise model's blind spot under real link
//! contention. On every *contended* family (oversubscribed trunks,
//! edge-list bottlenecks) the flow simulation must be at least as slow
//! as the analytic estimate; the harness prints a ✓/✗ verdict per row
//! so regressions are visible at a glance.

use crate::graph::models;
use crate::hw::Accelerator;
use crate::netsim::{LinkGraph, Simulation};
use crate::network::Cluster;
use crate::sim::{simulate, Schedule};
use crate::solver::solve as nest_solve;
use crate::util::csv::Csv;
use crate::util::table::Table;

use super::HarnessOpts;

/// A shipped edge-list example (embedded so the harness runs from any
/// working directory; the same file ships under `configs/`).
pub const EDGELIST_DUMBBELL: &str = include_str!("../../../configs/edgelist_dumbbell.json");

/// The shipped 4:1 spine-leaf edge-list (16 GPUs, 4 leaves, 2 spines) —
/// the contended fabric the fair-share perf smoke replays.
pub const EDGELIST_SPINELEAF: &str =
    include_str!("../../../configs/edgelist_spineleaf_4to1.json");

/// The 4:1 spine-leaf edge-list as (optimistic analytic cluster,
/// explicit link graph) — shared by the harness tables, the perf smoke,
/// and the benches, like [`dumbbell_topology`].
pub fn spineleaf_topology() -> (Cluster, LinkGraph) {
    let topo = LinkGraph::from_json(
        &crate::util::json::parse(EDGELIST_SPINELEAF).expect("shipped edge-list parses"),
    )
    .expect("shipped edge-list builds");
    let cluster = topo.approx_cluster(Accelerator::h100());
    (cluster, topo)
}

/// The dumbbell edge-list as (optimistic analytic cluster, explicit
/// link graph) — the construction every dumbbell consumer (harness
/// tables, perf smoke, refine benches/tests) must share so they all
/// measure the same fabric.
pub fn dumbbell_topology() -> (Cluster, LinkGraph) {
    let topo = LinkGraph::from_json(
        &crate::util::json::parse(EDGELIST_DUMBBELL).expect("shipped edge-list parses"),
    )
    .expect("shipped edge-list builds");
    let cluster = topo.approx_cluster(Accelerator::h100());
    (cluster, topo)
}

/// Deterministic cross-validation snapshot of the shipped dumbbell
/// edge-list (llama2-7b, serial solver): the golden-file suite pins
/// this rendered table to catch silent report-field drift. Every cell
/// is a pure function of the inputs — no wall-clock, no thread count
/// (the solver is forced serial; the flow engine is single-threaded
/// and bit-deterministic).
pub fn dumbbell_xval_snapshot() -> String {
    let (cluster, topo) = dumbbell_topology();
    let graph = models::by_name("llama2-7b", 1).expect("model exists");
    let opts = crate::solver::SolverOpts {
        threads: 1,
        ..Default::default()
    };
    let sol = nest_solve(&graph, &cluster, &opts).expect("dumbbell solvable");
    let ana = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
    let flow = Simulation::new().run(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB);
    let err = (flow.batch_time - ana.batch_time) / ana.batch_time;
    let mut tbl = Table::new(&[
        "topology",
        "model",
        "devices",
        "strategy",
        "analytic DES",
        "flow-sim",
        "error",
        "max link util",
        "flows",
    ]);
    tbl.row(vec![
        "edge-list dumbbell".into(),
        "llama2-7b".into(),
        cluster.n_devices().to_string(),
        sol.plan.strategy_string(),
        crate::util::table::fmt_time(ana.batch_time),
        crate::util::table::fmt_time(flow.batch_time),
        format!("{:+.2}%", err * 100.0),
        format!("{:.1}%", flow.max_link_util * 100.0),
        flow.n_flows.to_string(),
    ]);
    tbl.render()
}

/// One topology family of the cross-validation sweep.
struct Family {
    label: &'static str,
    /// Whether the scenario has link contention the analytic model
    /// cannot price (oversubscription / shared bottleneck links): there
    /// the flow simulation must be ≥ the analytic DES.
    contended: bool,
    cluster: Cluster,
    topo: LinkGraph,
}

fn families(quick: bool) -> Vec<Family> {
    let n = if quick { 64 } else { 128 };
    let mut out = Vec::new();
    let fat = Cluster::fat_tree_tpuv4(n);
    out.push(Family {
        label: "fat-tree",
        contended: false,
        topo: LinkGraph::from_cluster(&fat),
        cluster: fat,
    });
    let spine = Cluster::spine_leaf_h100(n, 4.0);
    out.push(Family {
        label: "spine-leaf 4:1",
        contended: true,
        topo: LinkGraph::from_cluster(&spine),
        cluster: spine,
    });
    let torus = Cluster::torus2d(8, if quick { 8 } else { 16 }, 50.0 * crate::hw::GB, 1e-6);
    out.push(Family {
        label: "torus2d",
        contended: false,
        topo: LinkGraph::from_cluster(&torus),
        cluster: torus,
    });
    let (cluster, edge) = dumbbell_topology();
    out.push(Family {
        label: "edge-list dumbbell",
        contended: true,
        cluster,
        topo: edge,
    });
    out
}

/// The cross-validation table: one row per topology family.
pub fn netsim_xval(opts: &HarnessOpts) {
    netsim_xval_quick(opts, false);
}

/// `quick = true` shrinks cluster sizes (used by tests and `--quick`).
pub fn netsim_xval_quick(opts: &HarnessOpts, quick: bool) -> bool {
    println!("== netsim cross-validation: analytic DES vs flow-level simulation ==");
    let mut tbl = Table::new(&[
        "topology",
        "model",
        "devices",
        "analytic DES",
        "flow-sim",
        "error",
        "max link util",
        "flows",
        "contended",
    ]);
    let mut csv = Csv::new(&[
        "topology",
        "model",
        "devices",
        "analytic_s",
        "flowsim_s",
        "error_pct",
        "max_link_util",
        "n_flows",
        "contended",
        "ok",
    ]);
    let model = "llama2-7b";
    let mut all_ok = true;
    // One Simulation across families: `--mode`/`--threads` land in
    // `opts.netsim`; reports are bit-identical for every setting.
    let mut sim = Simulation::with_opts(opts.netsim);
    for fam in families(quick) {
        let graph = models::by_name(model, 1).expect("model exists");
        let Some(sol) = nest_solve(&graph, &fam.cluster, &opts.solver) else {
            tbl.row(vec![
                fam.label.into(),
                model.into(),
                fam.cluster.n_devices().to_string(),
                "✗".into(),
                "✗".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            all_ok = false;
            continue;
        };
        let ana = simulate(&graph, &fam.cluster, &sol.plan, Schedule::OneFOneB);
        let flow = sim.run(&graph, &fam.cluster, &fam.topo, &sol.plan, Schedule::OneFOneB);
        let err = (flow.batch_time - ana.batch_time) / ana.batch_time;
        // Contended scenarios: flow-sim must never be faster than the
        // analytic estimate (the abstraction can only hide congestion).
        let ok = !fam.contended || flow.batch_time >= ana.batch_time * (1.0 - 1e-9);
        all_ok &= ok;
        tbl.row(vec![
            fam.label.into(),
            model.into(),
            fam.cluster.n_devices().to_string(),
            crate::util::table::fmt_time(ana.batch_time),
            crate::util::table::fmt_time(flow.batch_time),
            format!("{:+.1}%", err * 100.0),
            format!("{:.0}%", flow.max_link_util * 100.0),
            flow.n_flows.to_string(),
            if fam.contended {
                format!("yes {}", if ok { "✓" } else { "✗" })
            } else {
                "no".into()
            },
        ]);
        csv.row(vec![
            fam.label.into(),
            model.into(),
            fam.cluster.n_devices().to_string(),
            ana.batch_time.to_string(),
            flow.batch_time.to_string(),
            (err * 100.0).to_string(),
            flow.max_link_util.to_string(),
            flow.n_flows.to_string(),
            fam.contended.to_string(),
            ok.to_string(),
        ]);
    }
    println!("{}", tbl.render());
    println!(
        "flow-sim ≥ analytic on every contended scenario: {}",
        if all_ok { "✓" } else { "✗ REGRESSION" }
    );
    let _ = csv.write(format!("{}/netsim_xval.csv", opts.results_dir));
    all_ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xval_table_runs_and_contended_rows_hold() {
        let mut opts = HarnessOpts::quick();
        opts.results_dir = std::env::temp_dir()
            .join("nest_netsim_xval")
            .to_string_lossy()
            .into_owned();
        assert!(
            netsim_xval_quick(&opts, true),
            "flow-sim undercut the analytic DES on a contended topology"
        );
    }
}
