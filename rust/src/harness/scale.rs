//! `nest netsim-scale`: fleet-scale flow simulation on generated
//! fabrics, with the monolithic twin as a built-in exactness gate.
//!
//! The driver builds a seeded fat-tree ([`crate::netsim::topo::fattree`]),
//! synthesizes a deterministic rack-local flow mix ([`scale_workload`]),
//! and runs it decomposed ([`crate::netsim::SimMode::Decomposed`]) and
//! monolithic, reporting wall-clock, flows/sec, and the component
//! census. The two reports must agree to the bit — the run fails (and
//! the CI smoke exits nonzero) on any mismatch, making every
//! `netsim-scale` invocation a full-size decomposition proof.

use std::time::Instant;

use crate::netsim::{
    decompose, topo, FlowSpec, NetsimReport, SimMode, Simulation, TaskKind, Workload,
};
use crate::util::rng::Rng;
use crate::util::table::{fmt_time, Table};

/// Knobs of one `netsim-scale` run (CLI defaults live in `main.rs`).
#[derive(Debug, Clone)]
pub struct ScaleOpts {
    /// Fat-tree arity (even; k³/4 hosts — 16 → 1024 hosts).
    pub k: usize,
    /// Network-crossing flows to synthesize.
    pub flows: usize,
    /// Workload seed (fabric + routes are seed-independent).
    pub seed: u64,
    /// Decomposed-mode worker threads (0 = one per core).
    pub threads: usize,
    /// Fraction of flow batches confined to their rack (the rest roam
    /// the whole pod, merging that pod's components).
    pub locality: f64,
}

/// Synthesize a deterministic fleet-scale workload over `n_devices`
/// hosts grouped into racks of `rack` consecutive ids (inside pods of
/// `pod` ids): per rack, a chain of Compute-jitter → Transfer-batch
/// tasks totalling its share of `n_flows`. A batch is rack-local with
/// probability `locality`, else pod-scoped — so the link-sharing
/// partition sees many independent racks plus occasional pod-sized
/// merges, which is exactly the structure decomposed mode exploits.
/// Every flow has distinct endpoints and ≥ 64 KB, so all `n_flows`
/// cross the network.
pub fn scale_workload(
    n_devices: usize,
    rack: usize,
    pod: usize,
    n_flows: usize,
    locality: f64,
    seed: u64,
) -> Workload {
    assert!(rack >= 2 && n_devices >= rack, "rack must hold ≥ 2 hosts");
    assert!(pod >= rack && pod % rack == 0, "pods must be whole racks");
    assert!((0.0..=1.0).contains(&locality), "locality is a fraction");
    let n_racks = (n_devices / rack).max(1);
    let mut wl = Workload::new();
    let mut rng = Rng::new(seed);
    let per = n_flows / n_racks;
    let extra = n_flows % n_racks;
    for r in 0..n_racks {
        let rack_base = r * rack;
        let pod_base = (rack_base / pod) * pod;
        let pod_span = pod.min(n_devices - pod_base);
        let mut left = per + usize::from(r < extra);
        let mut prev: Option<u32> = None;
        while left > 0 {
            let batch = left.min(32);
            let deps: Vec<u32> = prev.into_iter().collect();
            let cmp = wl.add(
                TaskKind::Compute {
                    seconds: 1e-5 + 9e-5 * rng.gen_f64(),
                },
                &deps,
            );
            let (base, span) = if rng.gen_bool(locality) {
                (rack_base, rack)
            } else {
                (pod_base, pod_span)
            };
            let mut flows = Vec::with_capacity(batch);
            for _ in 0..batch {
                let src = base + rng.gen_range(span);
                let mut dst = base + rng.gen_range(span);
                if src == dst {
                    dst = base + (dst - base + 1) % span;
                }
                flows.push(FlowSpec {
                    src,
                    dst,
                    bytes: 64.0 * 1024.0 * (1.0 + 99.0 * rng.gen_f64()),
                });
            }
            prev = Some(wl.add(
                TaskKind::Transfer {
                    flows,
                    extra_latency: 0.0,
                },
                &[cmp],
            ));
            left -= batch;
        }
    }
    wl
}

/// Outcome of one `netsim-scale` run (the CLI maps `ok` to the exit
/// code; the bench smoke reads `flows_per_sec`).
#[derive(Debug, Clone)]
pub struct ScaleOutcome {
    pub report: NetsimReport,
    pub components: usize,
    pub wall_decomposed: f64,
    pub wall_monolithic: f64,
    pub flows_per_sec: f64,
    /// Decomposed report is bit-identical to the monolithic twin.
    pub ok: bool,
}

/// Build the fabric + workload, run decomposed and monolithic, print the
/// wall-clock / flows-per-sec table, and verify bit-identity.
pub fn netsim_scale(opts: &ScaleOpts) -> ScaleOutcome {
    println!("== netsim-scale: decomposed flow simulation at fabric scale ==");
    let t0 = Instant::now();
    let fabric = topo::fattree(opts.k);
    println!(
        "fabric:    {} ({} nodes; built in {})",
        fabric.describe(),
        fabric.nodes.len(),
        fmt_time(t0.elapsed().as_secs_f64()),
    );

    let rack = opts.k / 2;
    let pod = opts.k * opts.k / 4;
    let wl = scale_workload(
        fabric.n_devices(),
        rack,
        pod,
        opts.flows,
        opts.locality,
        opts.seed,
    );
    // Census pass for the table (run_decomposed repartitions internally;
    // one extra pass keeps the simulation path identical to production).
    let comps = decompose::partition(&fabric, &wl);
    let components = comps.len();
    let largest = comps.iter().map(|c| c.n_flows).max().unwrap_or(0);
    println!(
        "workload:  {} tasks, {} flows, seed {} → {} link-sharing components (largest {} flows)",
        wl.n_tasks(),
        opts.flows,
        opts.seed,
        components,
        largest,
    );
    drop(comps);

    let t = Instant::now();
    let dec = Simulation::new()
        .mode(SimMode::Decomposed)
        .threads(opts.threads)
        .run_workload(&fabric, &wl);
    let wall_dec = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let mono = Simulation::new()
        .mode(SimMode::Monolithic)
        .run_workload(&fabric, &wl);
    let wall_mono = t.elapsed().as_secs_f64();

    let flows_per_sec = if wall_dec > 0.0 {
        dec.n_flows as f64 / wall_dec
    } else {
        0.0
    };
    let mut table = Table::new(&["mode", "wall", "flows/sec", "sim batch", "events"]);
    for (name, rep, wall) in [
        ("decomposed", &dec, wall_dec),
        ("monolithic", &mono, wall_mono),
    ] {
        table.row(vec![
            name.into(),
            fmt_time(wall),
            format!("{:.0}", if wall > 0.0 { rep.n_flows as f64 / wall } else { 0.0 }),
            fmt_time(rep.batch_time),
            rep.events.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "headline:  {:.0} flows/sec decomposed ({:.2}× vs monolithic)",
        flows_per_sec,
        if wall_dec > 0.0 { wall_mono / wall_dec } else { 0.0 },
    );

    // Exactness gate: the decomposed report must match the monolithic
    // twin to the bit. assert_bits_eq names the first diverging field.
    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dec.assert_bits_eq(&mono, "netsim-scale decomposed vs monolithic twin");
    }))
    .is_ok();
    println!(
        "twin:      {}",
        if ok {
            "decomposed ≡ monolithic (bit-identical)"
        } else {
            "MISMATCH — decomposed diverged from the monolithic twin"
        }
    );

    ScaleOutcome {
        report: dec,
        components,
        wall_decomposed: wall_dec,
        wall_monolithic: wall_mono,
        flows_per_sec,
        ok,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_workload_is_deterministic_and_sized() {
        let wl = scale_workload(16, 2, 4, 1000, 0.9, 7);
        let wl2 = scale_workload(16, 2, 4, 1000, 0.9, 7);
        assert_eq!(wl.n_tasks(), wl2.n_tasks());
        let count = |w: &Workload| {
            let topo = topo::fattree(4);
            Simulation::new()
                .mode(SimMode::Monolithic)
                .run_workload(&topo, w)
                .n_flows
        };
        assert_eq!(count(&wl), 1000);
        assert_eq!(count(&wl2), 1000);
    }

    #[test]
    fn rack_local_mix_decomposes_into_many_components() {
        let topo = topo::fattree(4);
        let wl = scale_workload(16, 2, 4, 800, 1.0, 11);
        let comps = decompose::partition(&topo, &wl);
        // Pure rack-locality: one component per rack.
        assert_eq!(comps.len(), 8);
    }

    #[test]
    fn netsim_scale_quick_run_is_exact() {
        let out = netsim_scale(&ScaleOpts {
            k: 4,
            flows: 500,
            seed: 42,
            threads: 2,
            locality: 0.9,
        });
        assert!(out.ok, "decomposed diverged from monolithic");
        assert_eq!(out.report.n_flows, 500);
        assert!(out.flows_per_sec > 0.0);
    }
}
