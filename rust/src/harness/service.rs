//! `nest serve-bench`: the placement-service production headline —
//! queries/sec over a repeating query stream, with the cache-hit and
//! warm-start speedup breakdown and an elasticity migration-cost row.
//!
//! The stream cycles a co-design sweep grid (two models × three
//! cluster scales, 6 unique cells), so a 16-query run exercises every
//! service path: cold first-encounters, graph-neighbor warm starts
//! (same model on a scaled cluster), and pure cache hits. Every
//! non-hit answer is verified bit-identical against a freshly solved
//! cold twin — the serve-bench doubles as an end-to-end soundness
//! check, and [`ServeBenchReport::mismatches`] must be zero.

use std::time::Instant;

use crate::graph::models;
use crate::network::Cluster;
use crate::service::{ClusterDelta, PlacementService, Query, ServiceStats};
use crate::solver::solve_topk;
use crate::util::csv::Csv;
use crate::util::table::{fmt_bytes, fmt_time, Table};

use super::HarnessOpts;

/// Outcome of one serve-bench run.
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    /// Queries streamed through the service.
    pub queries: usize,
    /// Unique (model, cluster) cells in the stream.
    pub unique_cells: usize,
    /// Total service answer time (the qps denominator).
    pub serve_seconds: f64,
    /// Headline: queries per second through the service.
    pub qps: f64,
    /// Total cold-twin solve time over the same stream (what the
    /// service replaced).
    pub cold_seconds: f64,
    /// Mean cold/serve ratio over cache hits (how much a hit saves).
    pub hit_speedup: f64,
    /// Mean cold/serve ratio over warm-started solves (evaluation-order
    /// seeding only — modest by design; the plan is untouched).
    pub warm_speedup: f64,
    /// Served plans that were NOT bit-identical to their cold twin.
    /// Must be zero; the CLI exits nonzero otherwise.
    pub mismatches: usize,
    /// Migration cost of the elasticity row (`reconcile` after failing
    /// one outer switch-group): (param bytes moved, seconds).
    pub migration: Option<(f64, f64)>,
    pub stats: ServiceStats,
}

/// The sweep grid the stream cycles through: (label, graph ctor, devices).
fn cells() -> Vec<(&'static str, crate::graph::LayerGraph, usize)> {
    let mut out = Vec::new();
    for devices in [8usize, 16, 32] {
        out.push(("bert-large", models::bert_large(1), devices));
        out.push(("mixtral-790m", models::mixtral_scaled(1), devices));
    }
    out
}

/// Stream `n_queries` through a fresh [`PlacementService`] and report
/// queries/sec, the speedup breakdown, and an elasticity row. `quiet`
/// suppresses all printing (the perf smoke runs this as a metric).
pub fn serve_bench(opts: &HarnessOpts, n_queries: usize, quiet: bool) -> ServeBenchReport {
    let grid = cells();
    let queries: Vec<(usize, Query)> = (0..n_queries.max(1))
        .map(|i| {
            let (_, graph, devices) = &grid[i % grid.len()];
            (
                i % grid.len(),
                Query::new(
                    graph.clone(),
                    Cluster::v100_cluster(*devices),
                    opts.solver.clone(),
                ),
            )
        })
        .collect();

    // Cold twins, one per unique cell: the verification oracle and the
    // speedup denominator. Solved outside the timed loop.
    let mut cold: Vec<Option<(Vec<crate::solver::plan::PlacementPlan>, f64)>> =
        vec![None; grid.len()];
    for (cell, q) in &queries {
        if cold[*cell].is_none() {
            let top = solve_topk(&q.graph, &q.cluster, &q.opts, 1);
            cold[*cell] = Some((top.plans, top.solve_seconds));
        }
    }

    let mut svc = PlacementService::new(grid.len() * 2);
    let mut tbl = Table::new(&[
        "q", "model", "devices", "source", "serve", "cold", "speedup",
    ]);
    let mut csv = Csv::new(&["query", "model", "devices", "source", "serve_s", "cold_s"]);
    let mut serve_seconds = 0.0;
    let mut cold_seconds = 0.0;
    let mut mismatches = 0usize;
    let mut hit_ratios = Vec::new();
    let mut warm_ratios = Vec::new();

    for (i, (cell, q)) in queries.iter().enumerate() {
        let t0 = Instant::now();
        let served = svc.solve_topk(q, 1);
        let dt = t0.elapsed().as_secs_f64();
        serve_seconds += dt;

        let (cold_plans, cold_dt) = cold[*cell].as_ref().expect("twin solved above");
        cold_seconds += cold_dt;
        if served.plans != *cold_plans {
            mismatches += 1;
        }
        let source = if served.cache_hit {
            "hit"
        } else if served.warm_started {
            "warm"
        } else {
            "cold"
        };
        let ratio = cold_dt / dt.max(1e-9);
        match source {
            "hit" => hit_ratios.push(ratio),
            "warm" => warm_ratios.push(ratio),
            _ => {}
        }
        let (label, _, devices) = &grid[*cell];
        tbl.row(vec![
            (i + 1).to_string(),
            label.to_string(),
            devices.to_string(),
            source.into(),
            fmt_time(dt),
            fmt_time(*cold_dt),
            format!("{ratio:.1}x"),
        ]);
        csv.row(vec![
            (i + 1).to_string(),
            label.to_string(),
            devices.to_string(),
            source.into(),
            format!("{dt:.6}"),
            format!("{cold_dt:.6}"),
        ]);
    }

    // Snapshot the stream's cache counters before the elasticity row
    // (reconcile issues internal queries of its own).
    let stats = svc.stats();

    // Elasticity row: fail one outer switch-group under the largest
    // bert cell and price the migration.
    let (elabel, egraph, edevices) = &grid[grid.len() - 2];
    let eq = Query::new(
        egraph.clone(),
        Cluster::v100_cluster(*edevices),
        opts.solver.clone(),
    );
    let migration = svc
        .reconcile(&eq, &ClusterDelta::FailOuterGroups { groups: 1 })
        .ok()
        .map(|o| {
            let r = o.report();
            (r.delta.param_bytes, r.delta.migration_seconds)
        });

    let mean = |v: &[f64]| {
        if v.is_empty() {
            1.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let report = ServeBenchReport {
        queries: queries.len(),
        unique_cells: grid.len(),
        serve_seconds,
        qps: queries.len() as f64 / serve_seconds.max(1e-9),
        cold_seconds,
        hit_speedup: mean(&hit_ratios),
        warm_speedup: mean(&warm_ratios),
        mismatches,
        migration,
        stats,
    };

    if !quiet {
        println!(
            "== serve-bench: {} queries over {} unique (model, cluster) cells ==",
            report.queries, report.unique_cells
        );
        print!("{}", tbl.render());
        if let Some((bytes, secs)) = report.migration {
            println!(
                "elasticity: fail 1 outer group under {} @ {} devices -> migrate {} in {}",
                elabel,
                edevices,
                fmt_bytes(bytes),
                fmt_time(secs)
            );
        }
        println!(
            "serve: {:.1} queries/s ({} in {}), cold twins {}",
            report.qps,
            report.queries,
            fmt_time(report.serve_seconds),
            fmt_time(report.cold_seconds)
        );
        println!(
            "cache: {:.0}% hit rate ({} hits, {} warm, {} cold); hit speedup {:.0}x, \
             warm speedup {:.2}x",
            stats.hit_rate() * 100.0,
            stats.cache_hits,
            stats.warm_solves,
            stats.cold_solves,
            report.hit_speedup,
            report.warm_speedup
        );
        if report.mismatches > 0 {
            println!(
                "FAIL: {} served answer(s) diverged from their cold twins",
                report.mismatches
            );
        }
        let _ = csv.write(format!("{}/serve_bench.csv", opts.results_dir));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_is_sound_and_hits_cache() {
        let report = serve_bench(&HarnessOpts::quick().with_threads(1), 8, true);
        assert_eq!(report.queries, 8);
        assert_eq!(report.mismatches, 0, "served answers must match cold twins");
        // 8 queries over 6 cells → 2 hits; cells 2..6 warm from neighbors.
        assert_eq!(report.stats.cache_hits, 2);
        assert!(report.stats.warm_solves >= 1);
        assert!(report.qps > 0.0);
        let (bytes, secs) = report.migration.expect("elasticity row feasible");
        assert!(bytes > 0.0 && secs > 0.0);
    }
}
