//! Memory modeling (§3.3, Eq. 1) and ZeRO partitioning.
//!
//! Peak memory of a pipeline stage `S` at position `s` from the pipeline
//! end during 1F1B steady state:
//!
//! ```text
//! Mem(S, s) = Σ_{Lᵢ∈S} (2·weights + opt_states + activations)
//!             + (s − 1) · stashed_data
//! ```
//!
//! `2·weights` covers bf16 weights + bf16 gradients; `opt_states` is the
//! fp32 Adam triple (master copy, momentum, variance = 12 bytes/param).
//! `activations` is the working set of the microbatch in flight and
//! `stashed_data` the activations held for the additional in-flight
//! microbatches (s−1 of them under 1F1B; `B/d` under GPipe — callers pass
//! the stash count). ZeRO stages shard these terms across a degree-`z`
//! group; activation recomputation trades the stash for recomputed
//! forward FLOPs. Both are *native* to the solver: memory-infeasible DP
//! states are repaired by escalating ZeRO / enabling recomputation, not
//! rejected post hoc (Table 1).

use crate::graph::subgraph::SgConfig;
use crate::graph::Layer;

/// Bytes per parameter for bf16 weights.
pub const WEIGHT_BYTES: f64 = 2.0;
/// Bytes per parameter for bf16 gradients.
pub const GRAD_BYTES: f64 = 2.0;
/// Bytes per parameter for fp32 Adam state (master + m + v).
pub const OPT_BYTES: f64 = 12.0;

/// ZeRO sharding stage and degree (the degree is the size of the
/// data-parallel sub-group the states are sharded over, Table 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ZeroStage {
    None,
    /// Optimizer states sharded.
    Z1 { degree: usize },
    /// + gradients sharded.
    Z2 { degree: usize },
    /// + parameters sharded (adds per-microbatch all-gathers).
    Z3 { degree: usize },
}

impl ZeroStage {
    pub fn degree(&self) -> usize {
        match *self {
            ZeroStage::None => 1,
            ZeroStage::Z1 { degree } | ZeroStage::Z2 { degree } | ZeroStage::Z3 { degree } => {
                degree
            }
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            ZeroStage::None => "None".into(),
            ZeroStage::Z1 { degree } => format!("ZeRO-1 (degree {degree})"),
            ZeroStage::Z2 { degree } => format!("ZeRO-2 (degree {degree})"),
            ZeroStage::Z3 { degree } => format!("ZeRO-3 (degree {degree})"),
        }
    }
}

/// Memory-relevant execution choices for a stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemSpec {
    pub zero: ZeroStage,
    /// Activation recomputation: stash only stage-boundary inputs and
    /// re-materialize intermediates in backward (§3.3 strategy 2).
    pub recompute: bool,
}

impl MemSpec {
    pub fn plain() -> Self {
        MemSpec {
            zero: ZeroStage::None,
            recompute: false,
        }
    }
}

/// Static (batch-independent) bytes for one layer under `sg` and `zero`:
/// weights + gradients + optimizer states, per device.
pub fn layer_static_bytes(layer: &Layer, sg: &SgConfig, zero: ZeroStage) -> f64 {
    let p = layer.param_count_sharded(sg);
    let z = zero.degree() as f64;
    match zero {
        ZeroStage::None => p * (WEIGHT_BYTES + GRAD_BYTES + OPT_BYTES),
        ZeroStage::Z1 { .. } => p * (WEIGHT_BYTES + GRAD_BYTES + OPT_BYTES / z),
        ZeroStage::Z2 { .. } => p * (WEIGHT_BYTES + (GRAD_BYTES + OPT_BYTES) / z),
        ZeroStage::Z3 { .. } => p * (WEIGHT_BYTES + GRAD_BYTES + OPT_BYTES) / z,
    }
}

/// Peak bytes of a stage holding `layers`, with `stash_microbatches`
/// additional in-flight microbatches (Eq. 1's `(s−1)` term under 1F1B).
pub fn stage_peak_bytes(
    layers: &[Layer],
    tokens: f64,
    sg: &SgConfig,
    spec: &MemSpec,
    stash_microbatches: usize,
) -> f64 {
    let mut static_bytes = 0.0;
    let mut act_bytes = 0.0;
    for l in layers {
        static_bytes += layer_static_bytes(l, sg, spec.zero);
        act_bytes += l.act_stash_bytes(tokens, sg, spec.recompute);
    }
    // Working activations for the current microbatch + stash for the
    // others. With recomputation the *working* set still materializes one
    // layer's full activations transiently; we charge the max of one
    // layer's full footprint and the reduced stash.
    let working = if spec.recompute {
        layers
            .iter()
            .map(|l| l.act_stash_bytes(tokens, sg, false))
            .fold(0.0, f64::max)
    } else {
        0.0
    };
    static_bytes + act_bytes * (1.0 + stash_microbatches as f64) + working
}

/// Pick the cheapest memory spec that fits `capacity` bytes, escalating
/// exactly as the solver does (§4 "Memory-Optimization Co-design"):
/// plain → recompute → ZeRO-1 → ZeRO-2 → ZeRO-3, each ZeRO stage trying
/// power-of-two degrees up to `max_degree`. Returns `None` if even
/// ZeRO-3 at `max_degree` with recomputation does not fit.
///
/// On heterogeneous pools `capacity` must be the *minimum* HBM across
/// the lockstep group the stage occupies — replicas included
/// (`DevicePool::min_capacity`); every solver/baseline call site passes
/// exactly that, so a spec that "fits" fits the weakest device.
///
/// `prefer_recompute` pins the recomputation choice when the caller (the
/// DP) wants to cost both branches explicitly.
pub fn choose_spec(
    layers: &[Layer],
    tokens: f64,
    sg: &SgConfig,
    stash_microbatches: usize,
    capacity: f64,
    max_degree: usize,
    prefer_recompute: Option<bool>,
) -> Option<MemSpec> {
    let recompute_options: &[bool] = match prefer_recompute {
        Some(true) => &[true],
        Some(false) => &[false],
        None => &[false, true],
    };
    for &rc in recompute_options {
        let mut candidates: Vec<ZeroStage> = vec![ZeroStage::None];
        let mut z = 2;
        while z <= max_degree {
            candidates.push(ZeroStage::Z1 { degree: z });
            z *= 2;
        }
        let mut z = 2;
        while z <= max_degree {
            candidates.push(ZeroStage::Z2 { degree: z });
            z *= 2;
        }
        let mut z = 2;
        while z <= max_degree {
            candidates.push(ZeroStage::Z3 { degree: z });
            z *= 2;
        }
        for zero in candidates {
            let spec = MemSpec { zero, recompute: rc };
            if stage_peak_bytes(layers, tokens, sg, &spec, stash_microbatches) <= capacity {
                return Some(spec);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::subgraph::SgConfig;
    use crate::hw::GIB;
    use crate::util::prop;

    #[test]
    fn static_bytes_16x_params() {
        let g = models::gpt3_175b(1);
        let l = &g.layers[1];
        let sg = SgConfig::serial();
        let b = layer_static_bytes(l, &sg, ZeroStage::None);
        assert!((b / l.param_count() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn zero_stages_strictly_shrink() {
        let g = models::llama3_70b(1);
        let l = &g.layers[1];
        let sg = SgConfig::serial();
        let none = layer_static_bytes(l, &sg, ZeroStage::None);
        let z1 = layer_static_bytes(l, &sg, ZeroStage::Z1 { degree: 8 });
        let z2 = layer_static_bytes(l, &sg, ZeroStage::Z2 { degree: 8 });
        let z3 = layer_static_bytes(l, &sg, ZeroStage::Z3 { degree: 8 });
        assert!(none > z1 && z1 > z2 && z2 > z3);
        // ZeRO-3 at degree 8 shards everything: 16P/8 = 2P.
        assert!((z3 / l.param_count() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stash_term_linear_in_position() {
        let g = models::gpt3_175b(1);
        let layers = &g.layers[1..7];
        let sg = SgConfig::serial();
        let spec = MemSpec::plain();
        let m1 = stage_peak_bytes(layers, g.tokens, &sg, &spec, 0);
        let m2 = stage_peak_bytes(layers, g.tokens, &sg, &spec, 1);
        let m3 = stage_peak_bytes(layers, g.tokens, &sg, &spec, 2);
        let d1 = m2 - m1;
        let d2 = m3 - m2;
        assert!((d1 - d2).abs() / d1 < 1e-9, "linear in stash count");
        assert!(d1 > 0.0);
    }

    #[test]
    fn recompute_reduces_peak() {
        let g = models::llama2_7b(1);
        let layers = &g.layers[1..9];
        let sg = SgConfig::serial();
        let plain = stage_peak_bytes(layers, g.tokens, &sg, &MemSpec::plain(), 7);
        let rc = stage_peak_bytes(
            layers,
            g.tokens,
            &sg,
            &MemSpec {
                zero: ZeroStage::None,
                recompute: true,
            },
            7,
        );
        assert!(rc < plain, "recompute {rc} < plain {plain}");
    }

    #[test]
    fn choose_spec_escalates() {
        let g = models::llama3_70b(1);
        let layers = &g.layers[1..2]; // one 855M-param block
        let sg = SgConfig::serial();
        // Generous capacity → no ZeRO needed.
        let s = choose_spec(layers, g.tokens, &sg, 0, 64.0 * GIB, 8, Some(false)).unwrap();
        assert_eq!(s.zero, ZeroStage::None);
        // Table-7 regime: 24 GB forces ZeRO on a single-layer stage with
        // deep stash.
        let s = choose_spec(layers, g.tokens, &sg, 40, 24.0 * GIB, 8, None).unwrap();
        assert!(s.zero != ZeroStage::None || s.recompute);
        // Impossible capacity → None.
        assert!(choose_spec(layers, g.tokens, &sg, 0, 1e6, 8, None).is_none());
    }

    #[test]
    fn table7_bertlarge_needs_zero_at_120mb() {
        // BertLarge layer on a 120 MB device (Table 7): infeasible without
        // ZeRO, feasible with it.
        let g = models::bert_large(1);
        let layers = &g.layers[2..3];
        let sg = SgConfig::serial();
        let cap = 120e6;
        let plain = stage_peak_bytes(layers, g.tokens, &sg, &MemSpec::plain(), 0);
        assert!(plain > cap, "plain {plain} should exceed 120MB");
        let spec = choose_spec(layers, g.tokens, &sg, 0, cap, 8, None);
        assert!(spec.is_some(), "ZeRO should unlock 120MB placement");
        assert!(spec.unwrap().zero != ZeroStage::None);
    }

    #[test]
    fn prop_memory_monotone() {
        let g = models::gpt3_35b(1);
        prop::forall(100, 0xBEEF, |rng| {
            let sg = SgConfig::serial();
            let a = 1 + rng.gen_range(g.n_layers() - 2);
            let b = (a + 1 + rng.gen_range(g.n_layers() - a - 1)).min(g.n_layers());
            let spec = MemSpec::plain();
            // More layers → more memory.
            let small = stage_peak_bytes(&g.layers[a..b], g.tokens, &sg, &spec, 2);
            let big = stage_peak_bytes(&g.layers[a.saturating_sub(1)..b], g.tokens, &sg, &spec, 2);
            assert!(big >= small);
            // Bigger ZeRO degree → less memory.
            let z2 = MemSpec {
                zero: ZeroStage::Z2 { degree: 2 },
                recompute: false,
            };
            let z8 = MemSpec {
                zero: ZeroStage::Z2 { degree: 8 },
                recompute: false,
            };
            assert!(
                stage_peak_bytes(&g.layers[a..b], g.tokens, &sg, &z8, 2)
                    <= stage_peak_bytes(&g.layers[a..b], g.tokens, &sg, &z2, 2)
            );
        });
    }
}
