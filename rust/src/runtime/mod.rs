//! PJRT runtime: load AOT HLO-text artifacts and execute them (L3 ⇄ L1/L2
//! bridge).
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so results come back as one tuple literal that
//! [`Executable::run`] flattens.

pub mod manifest;

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus compilation entry points. One engine per thread —
/// the underlying client is not `Send`.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT engine (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given argument literals; returns the flattened
    /// elements of the result tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(out.to_tuple().context("untupling result")?)
    }

    /// Borrowed-argument variant: lets callers hoist argument literals
    /// (e.g. stage parameters, rebuilt only when they change) out of hot
    /// loops instead of re-uploading per call.
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<&xla::Literal>(args)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(out.to_tuple().context("untupling result")?)
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar i32 literal (Adam step counter).
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Locate the artifacts directory (build-time outputs of `make artifacts`).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_validate_shape() {
        assert!(literal_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }

    #[test]
    fn engine_loads_and_runs_probe() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let man = manifest::Manifest::load(dir.join("manifest.json")).unwrap();
        let engine = Engine::cpu().unwrap();
        let probe = &man.probes[0];
        let exe = engine.load(dir.join(&probe.file)).unwrap();
        let n: usize = probe.x_shape.iter().product();
        let x = literal_f32(
            &vec![0.1f32; n],
            &probe
                .x_shape
                .iter()
                .map(|&d| d as i64)
                .collect::<Vec<_>>(),
        )
        .unwrap();
        let out = exe.run(&[x]).unwrap();
        assert_eq!(out.len(), 1);
        let y: Vec<f32> = out[0].to_vec().unwrap();
        assert_eq!(y.len(), n);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stage_fwd_artifact_runs() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let man = manifest::Manifest::load(dir.join("manifest.json")).unwrap();
        let engine = Engine::cpu().unwrap();
        let st = &man.stages[0];
        let exe = engine.load(dir.join(&st.fwd)).unwrap();
        let mut args = Vec::new();
        for p in &st.params {
            let n: usize = p.shape.iter().product();
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            args.push(literal_f32(&vec![0.01f32; n.max(1)], &dims).unwrap());
        }
        // Stage 0 takes int32 tokens.
        let n: usize = st.x_shape.iter().product();
        let dims: Vec<i64> = st.x_shape.iter().map(|&d| d as i64).collect();
        args.push(literal_i32(&vec![1i32; n], &dims).unwrap());
        let out = exe.run(&args).unwrap();
        assert_eq!(out.len(), 1);
        let y: Vec<f32> = out[0].to_vec().unwrap();
        let expect: usize = st.y_shape.iter().product();
        assert_eq!(y.len(), expect);
    }
}
