//! `artifacts/manifest.json` parser: the contract between the python AOT
//! pipeline and the Rust trainer/profiler.

use anyhow::{Context, Result};
use std::path::Path;

use crate::util::json::{self, Json};

/// Model configuration the artifacts were built with.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub intermediate: usize,
    pub vocab: usize,
    pub seq: usize,
    pub mbs: usize,
    pub param_count: usize,
}

/// One parameter leaf (jit argument order).
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub path: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One pipeline stage's artifacts.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub index: usize,
    pub first: bool,
    pub last: bool,
    pub fwd: String,
    pub bwd: String,
    pub update: String,
    pub params: Vec<LeafSpec>,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
}

impl StageSpec {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// One profiler probe.
#[derive(Debug, Clone)]
pub struct ProbeSpec {
    pub file: String,
    pub hidden: usize,
    pub tokens: usize,
    pub x_shape: Vec<usize>,
    pub flops: f64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub cuts: Vec<usize>,
    pub stages: Vec<StageSpec>,
    pub probes: Vec<ProbeSpec>,
    pub train_step: Option<String>,
}

fn leafs(v: &Json) -> Result<Vec<LeafSpec>> {
    let arr = v.as_arr().context("params must be an array")?;
    arr.iter()
        .map(|p| {
            Ok(LeafSpec {
                path: p.get("path").as_str().context("leaf path")?.to_string(),
                shape: p
                    .get("shape")
                    .as_arr()
                    .context("leaf shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: p.get("dtype").as_str().unwrap_or("f32").to_string(),
            })
        })
        .collect()
}

fn shape_of(v: &Json) -> Vec<usize> {
    v.as_arr()
        .map(|a| a.iter().map(|d| d.as_usize().unwrap_or(0)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let c = v.get("config");
        let config = ModelConfig {
            n_layers: c.get("n_layers").as_usize().context("n_layers")?,
            hidden: c.get("hidden").as_usize().context("hidden")?,
            heads: c.get("heads").as_usize().context("heads")?,
            intermediate: c.get("intermediate").as_usize().context("intermediate")?,
            vocab: c.get("vocab").as_usize().context("vocab")?,
            seq: c.get("seq").as_usize().context("seq")?,
            mbs: c.get("mbs").as_usize().context("mbs")?,
            param_count: c.get("param_count").as_usize().unwrap_or(0),
        };
        let cuts = v
            .get("cuts")
            .as_arr()
            .context("cuts")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let mut stages = Vec::new();
        for s in v.get("stages").as_arr().context("stages")? {
            stages.push(StageSpec {
                index: s.get("index").as_usize().context("stage index")?,
                first: s.get("first").as_bool().unwrap_or(false),
                last: s.get("last").as_bool().unwrap_or(false),
                fwd: s.get("fwd").as_str().context("fwd file")?.to_string(),
                bwd: s.get("bwd").as_str().context("bwd file")?.to_string(),
                update: s.get("update").as_str().context("update file")?.to_string(),
                params: leafs(s.get("params"))?,
                x_shape: shape_of(s.get("x_shape")),
                x_dtype: s.get("x_dtype").as_str().unwrap_or("f32").to_string(),
                y_shape: shape_of(s.get("y_shape")),
            });
        }
        let mut probes = Vec::new();
        for p in v.get("probes").as_arr().unwrap_or(&[]) {
            probes.push(ProbeSpec {
                file: p.get("file").as_str().context("probe file")?.to_string(),
                hidden: p.get("hidden").as_usize().unwrap_or(0),
                tokens: p.get("tokens").as_usize().unwrap_or(0),
                x_shape: shape_of(p.get("x_shape")),
                flops: p.get("flops").as_f64().unwrap_or(0.0),
            });
        }
        let train_step = v
            .get("train_step")
            .get("file")
            .as_str()
            .map(|s| s.to_string());
        anyhow::ensure!(!stages.is_empty(), "manifest has no stages");
        Ok(Manifest {
            config,
            cuts,
            stages,
            probes,
            train_step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"n_layers": 2, "hidden": 64, "heads": 2, "intermediate": 128,
                 "vocab": 256, "seq": 16, "mbs": 2, "param_count": 123},
      "cuts": [0, 2, 4],
      "n_stages": 2,
      "stages": [
        {"index": 0, "first": true, "last": false,
         "fwd": "stage0_fwd.hlo.txt", "bwd": "stage0_bwd.hlo.txt",
         "update": "stage0_update.hlo.txt",
         "params": [{"path": "embed", "shape": [256, 64], "dtype": "f32"}],
         "x_shape": [2, 16], "x_dtype": "i32", "y_shape": [2, 16, 64]},
        {"index": 1, "first": false, "last": true,
         "fwd": "f", "bwd": "b", "update": "u",
         "params": [{"path": "head", "shape": [64, 256], "dtype": "f32"}],
         "x_shape": [2, 16, 64], "x_dtype": "f32", "y_shape": []}
      ],
      "probes": [{"file": "probe_h64.hlo.txt", "hidden": 64, "tokens": 32,
                  "x_shape": [2, 16, 64], "flops": 1e9}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.hidden, 64);
        assert_eq!(m.stages.len(), 2);
        assert!(m.stages[0].first && m.stages[1].last);
        assert_eq!(m.stages[0].params[0].numel(), 256 * 64);
        assert_eq!(m.stages[0].params[0].dims_i64(), vec![256, 64]);
        assert_eq!(m.probes[0].flops, 1e9);
        assert!(m.train_step.is_none());
        assert_eq!(m.cuts, vec![0, 2, 4]);
    }

    #[test]
    fn rejects_empty_stages() {
        let bad = SAMPLE.replace(
            r#""stages": ["#,
            r#""stages_x": ["#,
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        if let Some(dir) = super::super::artifacts_dir() {
            let m = Manifest::load(dir.join("manifest.json")).unwrap();
            assert!(m.stages.len() >= 2);
            assert!(m.stages[0].first);
            assert!(m.stages.last().unwrap().last);
            assert_eq!(m.stages.len(), m.cuts.len() - 1);
            // Every referenced artifact exists.
            for s in &m.stages {
                for f in [&s.fwd, &s.bwd, &s.update] {
                    assert!(dir.join(f).exists(), "{f}");
                }
            }
        }
    }
}
