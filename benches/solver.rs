//! Solver benchmarks (mini-criterion harness, `cargo bench --bench solver`).
//!
//! One bench per paper-relevant solve: the Table 4 runtime comparison and
//! the §Perf targets in EXPERIMENTS.md track these numbers.

use nest::baselines::mist;
use nest::graph::models;
use nest::harness::netsim::dumbbell_topology;
use nest::network::Cluster;
use nest::solver::exact::{solve_exact, ExactOpts};
use nest::solver::refine::refine;
use nest::solver::{solve, solve_topk, SolverOpts};
use nest::util::bench::{bench, bench_n, report_speedup};

fn main() {
    let opts = SolverOpts::default();

    // Cost-model construction (the per-config setup inside the solver).
    let g = models::gpt3_175b(1);
    let c = Cluster::fat_tree_tpuv4(1024);
    bench("cost_model_gpt3_1024", || {
        nest::cost::CostModel::new(&g, &c, nest::graph::subgraph::SgConfig::tp(8))
    });

    // End-to-end solves across model scale (Table 4 analogue).
    for (name, graph) in [
        ("bertlarge", models::bert_large(1)),
        ("llama2_7b", models::llama2_7b(1)),
        ("llama3_70b", models::llama3_70b(1)),
        ("gpt3_175b", models::gpt3_175b(1)),
        ("mixtral_8x7b", models::mixtral_8x7b(1)),
    ] {
        let c = Cluster::fat_tree_tpuv4(1024);
        bench_n(&format!("solve_{name}_fattree_1024"), 3, || {
            solve(&graph, &c, &opts)
        });
    }

    // Spine-leaf (Figure 7 cell) and the Mist comparison point.
    let g35 = models::gpt3_35b(1);
    let sl = Cluster::spine_leaf_h100(1024, 2.0);
    bench_n("solve_gpt3_35b_spineleaf_1024", 3, || solve(&g35, &sl, &opts));
    bench_n("mist_gpt3_35b_spineleaf_1024", 3, || mist::solve(&g35, &sl));

    // Exact small-cluster solver (§5.4 regime), serial for a stable
    // baseline comparable across machines.
    let mx = models::mixtral_scaled(1);
    let v = Cluster::v100_cluster(16);
    bench_n("solve_exact_mixtral790m_v100_16", 3, || {
        solve_exact(
            &mx,
            &v,
            &ExactOpts {
                threads: 1,
                ..Default::default()
            },
        )
    });

    // Heterogeneous pool: the mixed H100+V100 solve rebuilds its DP
    // tables per (stage count, dp width), so its overhead vs the
    // homogeneous fast path (the all-V100 twin on identical tiers) is
    // the number to watch.
    let g7 = models::llama2_7b(1);
    let hx = Cluster::hetero_pool(64);
    let hv = hx.with_uniform_accel(nest::hw::Accelerator::v100());
    bench_n("solve_llama2_7b_hetero_64", 3, || solve(&g7, &hx, &opts));
    bench_n("solve_llama2_7b_hetero_64_as_v100", 3, || {
        solve(&g7, &hv, &opts)
    });

    // Scaling with cluster size (the paper's 3 min – 1.5 h claim is about
    // growth with devices; ours must stay sub-minute).
    for n in [64usize, 256, 1024] {
        let c = Cluster::fat_tree_tpuv4(n);
        let g = models::gpt3_175b(1);
        bench_n(&format!("solve_gpt3_175b_fattree_{n}"), 3, || {
            solve(&g, &c, &opts)
        });
    }

    // Single- vs multi-thread solve (Table 4 wall-clock target): the
    // outer (sg, recompute) enumeration fans out over workers with a
    // shared pruning incumbent; plans are identical, only time differs.
    let g = models::gpt3_175b(1);
    let c = Cluster::fat_tree_tpuv4(256);
    let single = bench_n("solve_gpt3_175b_fattree_256_threads1", 3, || {
        solve(
            &g,
            &c,
            &SolverOpts {
                threads: 1,
                ..Default::default()
            },
        )
    });
    let multi = bench_n("solve_gpt3_175b_fattree_256_threads4", 3, || {
        solve(
            &g,
            &c,
            &SolverOpts {
                threads: 4,
                ..Default::default()
            },
        )
    });
    report_speedup("solve_gpt3_175b_256_4t_over_1t", &single, &multi);

    let g35 = models::gpt3_35b(1);
    let sl = Cluster::spine_leaf_h100(256, 2.0);
    let single = bench_n("solve_gpt3_35b_spineleaf_256_threads1", 3, || {
        solve(
            &g35,
            &sl,
            &SolverOpts {
                threads: 1,
                ..Default::default()
            },
        )
    });
    let multi = bench_n("solve_gpt3_35b_spineleaf_256_threads4", 3, || {
        solve(
            &g35,
            &sl,
            &SolverOpts {
                threads: 4,
                ..Default::default()
            },
        )
    });
    report_speedup("solve_gpt3_35b_256_4t_over_1t", &single, &multi);

    // K-best enumeration overhead: retaining the top-8 shortlist keeps a
    // looser pruning incumbent (the K-th, not the 1st), so this tracks
    // how much search the refinement loop's shortlist really costs over
    // the single-winner solve.
    let g = models::llama2_7b(1);
    let c = Cluster::fat_tree_tpuv4(256);
    let top1 = bench_n("solve_llama2_7b_fattree_256_top1", 3, || {
        solve_topk(&g, &c, &opts, 1)
    });
    let top8 = bench_n("solve_llama2_7b_fattree_256_top8", 3, || {
        solve_topk(&g, &c, &opts, 8)
    });
    report_speedup("solve_llama2_7b_256_top1_over_top8", &top8, &top1);

    // End-to-end refinement loop on the shipped dumbbell edge-list:
    // shortlist solve + K flow-level replays + re-rank. The top-8 run
    // is the bench-smoke's `solve_topk8_refine_dumbbell` twin — the
    // deepest shortlist the CI gate times.
    let (ec, edge) = dumbbell_topology();
    bench_n("refine_top4_llama2_7b_dumbbell", 3, || {
        refine(&g, &ec, &edge, &opts, 4)
    });
    bench_n("refine_top8_llama2_7b_dumbbell", 3, || {
        refine(&g, &ec, &edge, &opts, 8)
    });

    // Reference pricing (naive layer/tier walks) vs the O(1) range
    // tables, same search: the solver-side half of this PR's speedup.
    use nest::cost::PricingMode;
    let single_ref = bench_n("solve_llama2_7b_fattree_256_reference", 3, || {
        solve(
            &g,
            &c,
            &SolverOpts {
                threads: 1,
                pricing: PricingMode::Reference,
                ..Default::default()
            },
        )
    });
    let single_opt = bench_n("solve_llama2_7b_fattree_256_optimized", 3, || {
        solve(
            &g,
            &c,
            &SolverOpts {
                threads: 1,
                pricing: PricingMode::Optimized,
                ..Default::default()
            },
        )
    });
    report_speedup("solve_llama2_7b_256_tables_over_reference", &single_ref, &single_opt);

    // Placement service: fingerprinting must be negligible next to a
    // solve (it runs on every query), and a cache hit must be orders of
    // magnitude cheaper than the cold solve it replaces.
    use nest::service::{PlacementService, Query};
    let q = Query::new(
        models::llama2_7b(1),
        Cluster::fat_tree_tpuv4(256),
        SolverOpts::default(),
    );
    bench("service_query_fingerprint_llama2_7b", || q.fingerprint());
    let mut svc = PlacementService::new(8);
    let cold = bench_n("service_cold_solve_llama2_7b_256", 3, || {
        PlacementService::new(8).solve_topk(&q, 1)
    });
    svc.solve_topk(&q, 1); // populate the cache once
    let hit = bench_n("service_cache_hit_llama2_7b_256", 3, || {
        svc.solve_topk(&q, 1)
    });
    report_speedup("service_hit_over_cold_llama2_7b_256", &cold, &hit);
}
