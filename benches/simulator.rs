//! Discrete-event simulator benchmarks: every harness cell runs one DES
//! evaluation, and the MCMC baseline runs thousands of plan evaluations,
//! so both `simulate` and `build_plan` are hot.

use nest::baselines::{build_plan, even_cuts};
use nest::graph::models;
use nest::graph::subgraph::SgConfig;
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, SolverOpts};
use nest::util::bench::{bench, bench_n};

fn main() {
    let g = models::gpt3_175b(1);
    let c = Cluster::fat_tree_tpuv4(512);
    let plan = solve(&g, &c, &SolverOpts::default()).unwrap().plan;

    bench_n("des_gpt3_512dev_1f1b", 10, || {
        simulate(&g, &c, &plan, Schedule::OneFOneB)
    });
    bench_n("des_gpt3_512dev_gpipe", 10, || {
        simulate(&g, &c, &plan, Schedule::GPipe)
    });

    // The MCMC-hot path: candidate construction + evaluation.
    let cuts = even_cuts(g.n_layers(), 16);
    bench("build_plan_gpt3_p16", || {
        build_plan(&g, &c, "bench", SgConfig::tp(4), &cuts, 8, true, 8)
    });

    // DES scaling with microbatch count.
    let small = models::llama2_7b(1);
    let c64 = Cluster::fat_tree_tpuv4(64);
    let plan64 = solve(&small, &c64, &SolverOpts::default()).unwrap().plan;
    bench_n("des_llama2_64dev", 10, || {
        simulate(&small, &c64, &plan64, Schedule::OneFOneB)
    });
}
