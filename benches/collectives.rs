//! Collective cost-model benchmarks: these sit in the DP inner loop's
//! setup path (CostModel::new prices every layer's collectives), so they
//! must stay in the tens-of-nanoseconds range.

use nest::graph::models;
use nest::graph::subgraph::{layer_collectives, SgConfig};
use nest::network::Cluster;
use nest::util::bench::bench;

fn main() {
    let c = Cluster::spine_leaf_h100(1024, 2.0);
    let shape32 = c.compact_shape(32);
    let shape512 = c.compact_shape(512);

    bench("allreduce_32dev_100MB", || c.allreduce(1e8, &shape32));
    bench("allreduce_512dev_1GB", || c.allreduce(1e9, &shape512));
    bench("allgather_32dev_100MB", || c.allgather(1e8, &shape32));
    bench("alltoall_32dev_100MB", || c.alltoall(1e8, &shape32));
    bench("dp_allreduce_d8_stride64", || c.dp_allreduce(1e9, 8, 64));
    bench("compact_shape_512", || c.compact_shape(512));
    bench("p2p_time_l2_10MB", || c.p2p_time(2, 1e7));

    // Per-layer collective enumeration (graph-side cost).
    let g = models::mixtral_8x7b(1);
    let sg = SgConfig {
        tp: 1,
        sp: false,
        ep: 8,
        cp: 2,
    };
    bench("layer_collectives_moe_ep8cp2", || {
        layer_collectives(&g.layers[1], g.tokens, &sg)
    });
}
