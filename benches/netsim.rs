//! Flow-level simulator benchmarks: topology expansion + routing-table
//! construction, the max-min fair-share engine on a synthetic permutation
//! load, and an end-to-end plan lowering + replay. The engine sits in the
//! harness cross-validation path, so routing builds should stay in the
//! milliseconds and full batch replays in the tens of milliseconds at
//! 64 devices.

use nest::graph::models;
use nest::netsim::{self, FlowSpec, LinkGraph, TaskKind, Workload};
use nest::network::Cluster;
use nest::sim::Schedule;
use nest::solver::{solve, SolverOpts};
use nest::util::bench::{bench, bench_n};

fn main() {
    // Topology expansion + deterministic routing tables.
    let fat64 = Cluster::fat_tree_tpuv4(64);
    let spine128 = Cluster::spine_leaf_h100(128, 4.0);
    bench("linkgraph_from_cluster_64", || {
        LinkGraph::from_cluster(&fat64)
    });
    bench("linkgraph_from_cluster_128", || {
        LinkGraph::from_cluster(&spine128)
    });

    // Fair-share engine: 64-flow cross-spine permutation on a 4:1 trunk
    // (every flow shares the waist; one rate recomputation per event).
    let topo = LinkGraph::from_cluster(&spine128);
    bench("fairshare_64flow_permutation", || {
        let mut wl = Workload::new();
        let flows: Vec<FlowSpec> = (0..64)
            .map(|i| FlowSpec {
                src: i,
                dst: 64 + (i + 7) % 64,
                bytes: 1e8,
            })
            .collect();
        wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[],
        );
        netsim::fairshare::run(&topo, &wl)
    });

    // End-to-end: solve once, then lower + replay a full training batch.
    let graph = models::llama2_7b(1);
    let cluster = Cluster::spine_leaf_h100(64, 4.0);
    let sol = solve(&graph, &cluster, &SolverOpts::default()).expect("feasible");
    let topo = LinkGraph::from_cluster(&cluster);
    bench_n("netsim_llama2_batch_64dev", 5, || {
        netsim::simulate_flows(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB)
    });
}
