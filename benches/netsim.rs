//! Flow-level simulator benchmarks: topology expansion + routing-table
//! construction, the max-min fair-share engine on a synthetic permutation
//! load, and an end-to-end plan lowering + replay. The engine sits in the
//! harness cross-validation path, so routing builds should stay in the
//! milliseconds and full batch replays in the tens of milliseconds at
//! 64 devices.

use nest::graph::models;
use nest::harness::netsim::spineleaf_topology;
use nest::harness::scale::scale_workload;
use nest::netsim::{
    faults, flowgen, flows, topo, FaultSpec, FlowSpec, LinkGraph, MixSpec, RefillMode, SimMode,
    Simulation, TaskKind, Workload,
};
use nest::network::Cluster;
use nest::sim::Schedule;
use nest::solver::{solve, SolverOpts};
use nest::util::bench::{bench, bench_n, report_speedup};

fn main() {
    // Topology expansion + deterministic routing tables.
    let fat64 = Cluster::fat_tree_tpuv4(64);
    let spine128 = Cluster::spine_leaf_h100(128, 4.0);
    bench("linkgraph_from_cluster_64", || {
        LinkGraph::from_cluster(&fat64)
    });
    bench("linkgraph_from_cluster_128", || {
        LinkGraph::from_cluster(&spine128)
    });

    // Fair-share engine: 64-flow cross-spine permutation on a 4:1 trunk
    // (every flow shares the waist; one rate recomputation per event).
    let spine_topo = LinkGraph::from_cluster(&spine128);
    bench("fairshare_64flow_permutation", || {
        let mut wl = Workload::new();
        let flows: Vec<FlowSpec> = (0..64)
            .map(|i| FlowSpec {
                src: i,
                dst: 64 + (i + 7) % 64,
                bytes: 1e8,
            })
            .collect();
        wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[],
        );
        Simulation::new().run_workload(&spine_topo, &wl)
    });

    // Incremental vs full-refill rate maintenance on a staggered load
    // with many disjoint components (NVLink pairs) plus cross-spine
    // contenders: the case where re-solving only the dirty component
    // pays. Reports are bit-identical; only wall-clock differs.
    let staggered = || {
        let mut wl = Workload::new();
        let mut prev: Option<u32> = None;
        for round in 0..32u32 {
            let deps: Vec<u32> = prev.into_iter().collect();
            let cmp = wl.add(TaskKind::Compute { seconds: 1e-5 }, &deps);
            let mut flows = Vec::new();
            for p in 0..16usize {
                flows.push(FlowSpec {
                    src: 8 * p,
                    dst: 8 * p + 1,
                    bytes: 1e7 + round as f64 * 1e5,
                });
            }
            flows.push(FlowSpec {
                src: (round as usize) % 64,
                dst: 64 + (round as usize) % 64,
                bytes: 5e7,
            });
            prev = Some(wl.add(
                TaskKind::Transfer {
                    flows,
                    extra_latency: 0.0,
                },
                &[cmp],
            ));
        }
        wl
    };
    let mut inc_sim = Simulation::new().refill(RefillMode::Incremental);
    let inc = bench_n("fairshare_staggered_incremental", 5, || {
        inc_sim.run_workload(&spine_topo, &staggered())
    });
    let mut full_sim = Simulation::new().refill(RefillMode::FullRefill);
    let full = bench_n("fairshare_staggered_full_refill", 5, || {
        full_sim.run_workload(&spine_topo, &staggered())
    });
    report_speedup("fairshare_incremental_over_full", &full, &inc);

    // End-to-end: solve once, then lower + replay a full training batch.
    let graph = models::llama2_7b(1);
    let cluster = Cluster::spine_leaf_h100(64, 4.0);
    let sol = solve(&graph, &cluster, &SolverOpts::default()).expect("feasible");
    let batch_topo = LinkGraph::from_cluster(&cluster);
    bench_n("netsim_llama2_batch_64dev", 5, || {
        Simulation::new().run(&graph, &cluster, &batch_topo, &sol.plan, Schedule::OneFOneB)
    });

    // The shipped 4:1 spine-leaf edge-list the perf smoke gates, with a
    // reused engine (the smoke's exact configuration).
    let (scluster, stopo) = spineleaf_topology();
    let ssol = solve(&graph, &scluster, &SolverOpts::default()).expect("feasible");
    let mut ssim = Simulation::new();
    bench_n("netsim_llama2_batch_spineleaf_edgelist", 5, || {
        ssim.run(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB)
    });

    // Background-flow generation + injection + mixed replay on the same
    // edge-list: the `nest mix` / `refine --bg-load` inner loop (one
    // load level of the sweep). Generation is a pure function of
    // (topo, spec), so it reruns inside the closure alongside the
    // lower + inject + fair-share path it feeds.
    let base = ssim.run(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB);
    let mspec = MixSpec::at_load(0.5, base.batch_time, 0xB6);
    let mut mix_sim = Simulation::new();
    bench_n("flowgen_mix_spineleaf_edgelist", 5, || {
        let mix = flowgen::generate(&stopo, &mspec);
        let mut mwl = flows::lower(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB);
        flowgen::inject(&mut mwl, &mix);
        mix_sim.run_workload(&stopo, &mwl)
    });

    // Seeded fault draw + straggler lowering + capacity-event replay on
    // the same edge-list: the `nest chaos` / `refine --fault-severity`
    // inner loop (one scenario of one severity level). The draw is a
    // pure function of (topo, spec), so it reruns inside the closure
    // alongside the lower_faulted + inject + fair-share path it feeds.
    let fspec = FaultSpec::at_severity(0.6, base.batch_time, 0xFA17);
    let mut fault_sim = Simulation::new();
    bench_n("faults_scenario_spineleaf_edgelist", 5, || {
        let sc = faults::draw(&stopo, &fspec);
        let mut fwl = flows::lower_faulted(
            &graph,
            &scluster,
            &stopo,
            &ssol.plan,
            Schedule::OneFOneB,
            Some(&sc),
        );
        faults::inject(&mut fwl, &stopo, &sc);
        fault_sim.run_workload(&stopo, &fwl)
    });

    // Decomposed vs monolithic on a generated spine-leaf fabric with a
    // rack-local flow mix — the workload whose link-sharing partition
    // has enough independent components for the fan-out to pay.
    // Reports are bit-identical; only wall-clock differs.
    let fabric = topo::spineleaf(16, 8, 4.0);
    let wl = scale_workload(fabric.n_devices(), 8, 32, 20_000, 0.9, 42);
    let mut mono_sim = Simulation::new().mode(SimMode::Monolithic);
    let mono = bench_n("netsim_monolithic_spineleaf", 3, || {
        mono_sim.run_workload(&fabric, &wl)
    });
    let mut dec_sim = Simulation::new().mode(SimMode::Decomposed).threads(0);
    let dec = bench_n("netsim_decomposed_spineleaf", 3, || {
        dec_sim.run_workload(&fabric, &wl)
    });
    report_speedup("netsim_decomposed_over_monolithic", &mono, &dec);
}
