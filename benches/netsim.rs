//! Flow-level simulator benchmarks: topology expansion + routing-table
//! construction, the max-min fair-share engine on a synthetic permutation
//! load, and an end-to-end plan lowering + replay. The engine sits in the
//! harness cross-validation path, so routing builds should stay in the
//! milliseconds and full batch replays in the tens of milliseconds at
//! 64 devices.

use nest::graph::models;
use nest::harness::netsim::spineleaf_topology;
use nest::netsim::{self, FairshareEngine, FlowSpec, LinkGraph, RefillMode, TaskKind, Workload};
use nest::network::Cluster;
use nest::sim::Schedule;
use nest::solver::{solve, SolverOpts};
use nest::util::bench::{bench, bench_n, report_speedup};

fn main() {
    // Topology expansion + deterministic routing tables.
    let fat64 = Cluster::fat_tree_tpuv4(64);
    let spine128 = Cluster::spine_leaf_h100(128, 4.0);
    bench("linkgraph_from_cluster_64", || {
        LinkGraph::from_cluster(&fat64)
    });
    bench("linkgraph_from_cluster_128", || {
        LinkGraph::from_cluster(&spine128)
    });

    // Fair-share engine: 64-flow cross-spine permutation on a 4:1 trunk
    // (every flow shares the waist; one rate recomputation per event).
    let topo = LinkGraph::from_cluster(&spine128);
    bench("fairshare_64flow_permutation", || {
        let mut wl = Workload::new();
        let flows: Vec<FlowSpec> = (0..64)
            .map(|i| FlowSpec {
                src: i,
                dst: 64 + (i + 7) % 64,
                bytes: 1e8,
            })
            .collect();
        wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[],
        );
        netsim::fairshare::run(&topo, &wl)
    });

    // Incremental vs full-refill rate maintenance on a staggered load
    // with many disjoint components (NVLink pairs) plus cross-spine
    // contenders: the case where re-solving only the dirty component
    // pays. Reports are bit-identical; only wall-clock differs.
    let staggered = || {
        let mut wl = Workload::new();
        let mut prev: Option<u32> = None;
        for round in 0..32u32 {
            let deps: Vec<u32> = prev.into_iter().collect();
            let cmp = wl.add(TaskKind::Compute { seconds: 1e-5 }, &deps);
            let mut flows = Vec::new();
            for p in 0..16usize {
                flows.push(FlowSpec {
                    src: 8 * p,
                    dst: 8 * p + 1,
                    bytes: 1e7 + round as f64 * 1e5,
                });
            }
            flows.push(FlowSpec {
                src: (round as usize) % 64,
                dst: 64 + (round as usize) % 64,
                bytes: 5e7,
            });
            prev = Some(wl.add(
                TaskKind::Transfer {
                    flows,
                    extra_latency: 0.0,
                },
                &[cmp],
            ));
        }
        wl
    };
    let mut engine = FairshareEngine::new(&topo);
    let inc = bench_n("fairshare_staggered_incremental", 5, || {
        engine.run_with_mode(&topo, &staggered(), RefillMode::Incremental)
    });
    let full = bench_n("fairshare_staggered_full_refill", 5, || {
        engine.run_with_mode(&topo, &staggered(), RefillMode::FullRefill)
    });
    report_speedup("fairshare_incremental_over_full", &full, &inc);

    // End-to-end: solve once, then lower + replay a full training batch.
    let graph = models::llama2_7b(1);
    let cluster = Cluster::spine_leaf_h100(64, 4.0);
    let sol = solve(&graph, &cluster, &SolverOpts::default()).expect("feasible");
    let topo = LinkGraph::from_cluster(&cluster);
    bench_n("netsim_llama2_batch_64dev", 5, || {
        netsim::simulate_flows(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB)
    });

    // The shipped 4:1 spine-leaf edge-list the perf smoke gates, with a
    // reused engine (the smoke's exact configuration).
    let (scluster, stopo) = spineleaf_topology();
    let ssol = solve(&graph, &scluster, &SolverOpts::default()).expect("feasible");
    let mut sengine = FairshareEngine::new(&stopo);
    bench_n("netsim_llama2_batch_spineleaf_edgelist", 5, || {
        netsim::simulate_flows_with(
            &mut sengine,
            &graph,
            &scluster,
            &stopo,
            &ssol.plan,
            Schedule::OneFOneB,
        )
    });
}
