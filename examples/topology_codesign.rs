//! Topology co-design sweep (the paper's motivation for "co-designing
//! parallelization strategies and datacenter interconnects", §1/§7).
//!
//! Sweeps the spine-tier oversubscription ratio of a 256-GPU H100
//! spine-leaf cluster and shows how NEST's chosen strategy *adapts*:
//! as the cross-rack links degrade, the solver shifts from wide data
//! parallelism (communication-hungry gradient sync across racks) toward
//! deeper pipelines that keep heavy traffic inside racks — while
//! topology-agnostic Phaze keeps the same plan and pays for it.

use nest::baselines::phaze;
use nest::graph::models;
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, SolverOpts};
use nest::util::table::Table;

fn main() {
    let model = "gpt3-175b";
    let graph = models::by_name(model, 1).unwrap();
    let opts = SolverOpts::default();

    let mut tbl = Table::new(&[
        "oversubscription",
        "nest strategy",
        "nest tput",
        "phaze strategy",
        "phaze tput",
        "nest gain",
    ]);

    for oversub in [1.0f64, 2.0, 4.0, 8.0] {
        let cluster = Cluster::spine_leaf_h100(256, oversub);
        let nest = solve(&graph, &cluster, &opts).expect("nest plan");
        let nest_rep = simulate(&graph, &cluster, &nest.plan, Schedule::OneFOneB);

        let (phaze_strategy, phaze_tput) = match phaze::solve(&graph, &cluster, &opts) {
            Some(p) => {
                let r = simulate(&graph, &cluster, &p, Schedule::OneFOneB);
                (p.strategy_string(), r.throughput)
            }
            None => ("✗".into(), 0.0),
        };

        let gain = if phaze_tput > 0.0 {
            format!("{:.2}x", nest_rep.throughput / phaze_tput)
        } else {
            "∞".into()
        };
        tbl.row(vec![
            format!("{oversub}:1"),
            nest.plan.strategy_string(),
            format!("{:.1}/s", nest_rep.throughput),
            phaze_strategy,
            format!("{phaze_tput:.1}/s"),
            gain,
        ]);
    }

    println!("== {model} on 256×H100 spine-leaf, oversubscription sweep ==");
    println!("{}", tbl.render());
    println!(
        "\nReading: as cross-rack bandwidth shrinks, NEST re-balances stage\n\
         cuts and parallelism to keep hot traffic inside racks; a network-\n\
         agnostic search cannot react, so its realized throughput degrades."
    );
}
