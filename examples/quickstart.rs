//! Quickstart: solve a placement with NEST's DP and inspect the plan.
//!
//! ```text
//! cargo run --release --example quickstart [-- <model> <devices>]
//! ```
//!
//! Solves Llama2-7B on a 64-device TPUv4 fat-tree by default, prints the
//! Table-2-style strategy, the per-stage layout (layers, devices, memory
//! spec, communication level to the next stage), and a discrete-event
//! evaluation of the plan.

use nest::graph::models;
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, SolverOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("llama2-7b");
    let devices: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);

    let graph = models::by_name(model, 1).expect("unknown model");
    let cluster = Cluster::fat_tree_tpuv4(devices);

    println!("model:   {} ({:.1}B params)", model, graph.total_params() / 1e9);
    println!("cluster: {}", cluster.describe());

    let sol = solve(&graph, &cluster, &SolverOpts::default()).expect("no feasible placement");
    println!(
        "\nsolved in {} — explored {} DP states across {} configurations",
        nest::util::table::fmt_time(sol.solve_seconds),
        sol.dp_states,
        sol.configs_tried
    );
    println!("\n{}", sol.plan.describe());

    sol.plan
        .validate(&graph, &cluster)
        .expect("plan failed validation");

    let rep = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
    println!(
        "\nDES evaluation: batch {} | {:.1} samples/s | comm share {:.1}% | bubble {:.1}%",
        nest::util::table::fmt_time(rep.batch_time),
        rep.throughput,
        rep.comm_fraction * 100.0,
        rep.bubble_fraction * 100.0,
    );
}
