//! ZeRO ablation (Table 7 / Appendix C.3): how adaptive ZeRO unlocks
//! training on memory-constrained accelerators.
//!
//! Sweeps device HBM capacity for Llama3-70B on a 1024-device fat-tree
//! and reports, per capacity: feasibility without any memory tricks,
//! with recomputation only, and with NEST's full adaptive ZeRO — plus
//! the strategy and ZeRO stages the solver chose.

use nest::graph::models;
use nest::hw::GIB;
use nest::memory::ZeroStage;
use nest::network::Cluster;
use nest::solver::{solve, SolverOpts};
use nest::util::table::Table;

fn main() {
    let graph = models::llama3_70b(1);
    let mut tbl = Table::new(&[
        "HBM/device",
        "plain",
        "recompute only",
        "full (ZeRO adaptive)",
        "chosen strategy",
        "ZeRO stages used",
    ]);

    for cap_gb in [80.0f64, 48.0, 24.0, 16.0] {
        let mut cluster = Cluster::fat_tree_tpuv4(1024);
        cluster.shrink_capacity(cap_gb * GIB);

        let plain = solve(
            &graph,
            &cluster,
            &SolverOpts {
                zero_max_degree: 1,
                try_recompute: false,
                ..Default::default()
            },
        );
        let rc_only = solve(
            &graph,
            &cluster,
            &SolverOpts {
                zero_max_degree: 1,
                ..Default::default()
            },
        );
        let full = solve(&graph, &cluster, &SolverOpts::default());

        let feas = |s: &Option<nest::solver::Solution>| {
            s.as_ref()
                .map(|s| format!("{:.0} samp/s", s.plan.throughput(graph.global_batch)))
                .unwrap_or_else(|| "✗".into())
        };
        let (strategy, zeros) = match &full {
            Some(s) => {
                let mut used: Vec<String> = s
                    .plan
                    .stages
                    .iter()
                    .map(|st| st.mem.zero)
                    .filter(|z| *z != ZeroStage::None)
                    .map(|z| z.describe())
                    .collect();
                used.sort();
                used.dedup();
                (
                    s.plan.strategy_string(),
                    if used.is_empty() {
                        "none needed".into()
                    } else {
                        used.join(", ")
                    },
                )
            }
            None => ("✗".into(), "-".into()),
        };
        tbl.row(vec![
            format!("{cap_gb:.0} GB"),
            feas(&plain),
            feas(&rc_only),
            feas(&full),
            strategy,
            zeros,
        ]);
    }

    println!("== Llama3-70B on 1024 devices: memory-capacity ablation (Table 7 style) ==");
    println!("{}", tbl.render());
    println!(
        "\nReading: as capacity shrinks, plain placement dies first, then\n\
         recomputation alone stops sufficing; adaptive ZeRO (stage and degree\n\
         chosen per pipeline stage inside the DP) keeps training feasible —\n\
         exactly the Table 7 behaviour."
    );
}
