//! Netsim demo: what link contention does to a placement plan.
//!
//! ```text
//! cargo run --release --example netsim_contention [-- <model> <devices>]
//! ```
//!
//! Solves the same model on a 1:1 and a 4:1-oversubscribed spine-leaf
//! cluster, then replays each plan through the flow-level simulator.
//! The analytic DES and the flow simulation agree on the clean fabric;
//! on the oversubscribed one the flow simulation exposes the congestion
//! the level-wise abstraction prices only approximately — including
//! cross-replica interference on the shared spine trunks. Finishes with
//! the hottest links so the bottleneck is visible by name.

use nest::graph::models;
use nest::netsim::{LinkGraph, Simulation};
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, SolverOpts};
use nest::util::table::fmt_time;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("llama2-7b");
    let devices: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);

    let graph = models::by_name(model, 1).expect("unknown model");
    println!(
        "model: {} ({:.1}B params)\n",
        model,
        graph.total_params() / 1e9
    );

    for (label, oversub) in [("1:1 spine", 1.0), ("4:1 spine", 4.0)] {
        let cluster = Cluster::spine_leaf_h100(devices, oversub);
        let topo = LinkGraph::from_cluster(&cluster);
        println!("== {label}: {} ==", cluster.describe());
        let sol = solve(&graph, &cluster, &SolverOpts::default())
            .expect("no feasible placement");
        println!("plan: {}", sol.plan.strategy_string());
        let ana = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
        let flow = Simulation::new().run(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB);
        let err = (flow.batch_time - ana.batch_time) / ana.batch_time;
        println!(
            "analytic DES {}  |  flow-sim {}  |  contention error {:+.1}%",
            fmt_time(ana.batch_time),
            fmt_time(flow.batch_time),
            err * 100.0
        );
        println!("hottest links:");
        for u in flow.link_util.iter().take(4) {
            println!("  {:>6.1}%  {}", u.utilization * 100.0, u.name);
        }
        println!();
    }
}
