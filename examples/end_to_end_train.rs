//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end_train
//!   [-- --steps N --microbatches M --dp D]
//! ```
//!
//! This is the composition proof required of the reproduction
//! (DESIGN.md §3, EXPERIMENTS.md §E2E). In one run it:
//!
//! 1. **Profiles** the Pallas/JAX probe artifacts on the PJRT CPU
//!    backend and calibrates the analytical compute model (L1/L2 → cost
//!    model).
//! 2. **Solves** placement for the artifact transformer on a 4-thread-
//!    device cluster with the calibrated accelerator and **predicts**
//!    step time with the discrete-event simulator.
//! 3. **Executes** real 1F1B pipeline-parallel training across stage
//!    threads running the AOT HLO artifacts — the Pallas flash-attention
//!    kernel included — on the learnable successor language, logging the
//!    loss curve.
//! 4. **Compares** the measured step time and stage utilization against
//!    the simulator's prediction.

use nest::graph::models;
use nest::hw::GB;
use nest::network::{Cluster, Tier};
use nest::profiler::calibrate;
use nest::runtime::{artifacts_dir, manifest::Manifest};
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, SolverOpts};
use nest::trainer::{train, TrainOpts};
use nest::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).collect());
    let steps = args.get_usize("steps", 30);
    let microbatches = args.get_usize("microbatches", 8);
    let dp = args.get_usize("dp", 1);
    args.finish().unwrap();

    let dir = artifacts_dir().expect("artifacts/ missing — run `make artifacts` first");
    let man = Manifest::load(dir.join("manifest.json")).unwrap();
    let cfg = &man.config;
    println!(
        "== E2E: {}-layer transformer, {:.1}M params, {} stages, dp={} ==",
        cfg.n_layers,
        cfg.param_count as f64 / 1e6,
        man.stages.len(),
        dp
    );

    // ---- 1. Profile & calibrate ------------------------------------------
    println!("\n[1/4] profiling probe artifacts on PJRT CPU…");
    let cal = calibrate(&dir, 5).expect("calibration failed");
    for p in &cal.probes {
        println!(
            "  block h={:4}: median {}, {:.2} GFLOP/s achieved",
            p.hidden,
            nest::util::table::fmt_time(p.median_seconds),
            p.achieved_flops_per_s / 1e9
        );
    }

    // ---- 2. Solve + predict ----------------------------------------------
    println!("\n[2/4] solving placement on the calibrated thread-device cluster…");
    let graph = models::tiny_transformer(cfg.n_layers, cfg.hidden, cfg.seq, cfg.mbs);
    let p = man.stages.len();
    let cluster = Cluster {
        name: format!("cpu-threads-{}", p * dp),
        pool: nest::hw::DevicePool::uniform(cal.accel_for_hidden(cfg.hidden), p * dp),
        tiers: vec![Tier {
            name: "shm".into(),
            arity: p * dp,
            link_bw: 10.0 * GB, // memcpy through channels
            latency: 5e-6,
            oversub: 1.0,
        }],
    };
    let sol = solve(&graph, &cluster, &SolverOpts::default());
    if let Some(s) = &sol {
        println!(
            "  NEST would choose {} on this cluster (batch model {})",
            s.plan.strategy_string(),
            nest::util::table::fmt_time(s.plan.batch_time)
        );
    }
    // Predict the *baked* artifact decomposition (even cuts from aot.py).
    let cuts: Vec<usize> = man.cuts.clone();
    let baked = nest::baselines::build_plan(
        &graph,
        &cluster,
        "artifacts",
        nest::graph::subgraph::SgConfig::serial(),
        &cuts,
        dp,
        false,
        1,
    )
    .expect("baked plan infeasible?");
    let mut baked = baked;
    baked.n_microbatches = microbatches;
    let pred = simulate(&graph, &cluster, &baked, Schedule::OneFOneB);
    let pred_step = pred.batch_time;
    println!(
        "  DES prediction for the baked {}-stage pipeline: {} per step",
        p,
        nest::util::table::fmt_time(pred_step)
    );

    // ---- 3. Real pipeline training ----------------------------------------
    println!("\n[3/4] real 1F1B pipeline training ({} threads)…", p * dp);
    let opts = TrainOpts {
        steps,
        microbatches,
        dp_width: dp,
        link_delay: 0.0,
        seed: 42,
        log_every: (steps / 10).max(1),
    };
    let rep = train(&dir, &opts).expect("training failed");

    // ---- 4. Compare ---------------------------------------------------------
    println!("\n[4/4] summary");
    let measured_step = nest::util::stats::median(&rep.step_times[rep.step_times.len() / 2..]);
    println!(
        "  loss: {:.4} → {:.4} over {} steps (ln V = {:.2})",
        rep.losses.first().unwrap(),
        rep.losses.last().unwrap(),
        steps,
        (cfg.vocab as f64).ln()
    );
    println!(
        "  throughput: {:.0} tokens/s | measured step {} vs DES prediction {} ({:.2}x)",
        rep.tokens_per_s,
        nest::util::table::fmt_time(measured_step),
        nest::util::table::fmt_time(pred_step),
        measured_step / pred_step
    );
    println!("  stage busy fractions: {:?}", rep.stage_busy);
    assert!(
        rep.losses.last().unwrap() < rep.losses.first().unwrap(),
        "loss did not decrease!"
    );
    println!("\nE2E OK: L1 Pallas kernel → L2 JAX stages → L3 Rust 1F1B coordinator all compose.");
}
